//! Weighted federated averaging (paper §3.1), as a **streaming, sparse-native**
//! operation.
//!
//! The aggregation rule is FedAvg's sample-weighted mean,
//! `Theta_{t+1} = sum_i (n_i / n) Theta_t^i` — Eq. 2 of the paper modulo its
//! extra `1/m` factor, which would shrink the aggregate by the cohort size
//! and contradicts both Eq. 1 and the cited McMahan et al.; DESIGN.md §4
//! records this as a presumed typo. Masked uploads are averaged exactly as
//! received (zeros included), which is the paper-literal semantics of
//! Alg. 2/4.
//!
//! Since the transport refactor the server no longer barriers on the full
//! cohort: decoded [`crate::transport::codec::WireUpdate`] payloads are
//! folded into an [`Aggregator`] as they arrive, in whatever order the
//! engine pool completes them — and since the sparse-native refactor a
//! sparse wire body folds in **O(nnz)**, never touching the p - nnz
//! coordinates the client masked away. Per-round server cost is
//! O(sum_i nnz_i + p): the only O(p) passes are aggregator construction
//! and `finish`, once each. Two implementations:
//!
//! * [`StreamingFedAvg`] — O(p) server memory (one fixed-point accumulator
//!   per parameter, no per-client buffering). The weighted numerator
//!   `sum_i n_i * v_ij` accumulates in 128-bit fixed point (scale 2^-64),
//!   so folds are integer additions — associative and commutative — and the
//!   result is **bit-identical for every arrival order** and bit-identical
//!   between the dense and sparse fold paths (a zero coordinate contributes
//!   the integer 0; skipping it is the same sum). Under
//!   [`MaskTarget::Delta`] the aggregator carries the broadcast baseline
//!   pre-rounded onto the same fixed-point grid
//!   (`grid[j] = round(b_j * 2^64)`), so a client's unsent masked
//!   coordinate contributes the exact integer product `n_i * grid[j]` —
//!   and the whole cohort's baseline mass collapses to
//!   `(total - sent[j]) * grid[j]`, added once per coordinate at `finish`.
//!   Integer distributivity is what makes that single `finish`-time
//!   addition bit-identical to folding each client's baseline term
//!   separately; it deletes the old per-contribution
//!   `apply_delta_target` O(p) reconstruction copy entirely.
//! * [`BufferingAttentive`] — attentive aggregation (Ji et al. [11]) needs
//!   the whole cohort to form its softmax weights, so it buffers decoded
//!   updates (O(k*p), inherent to the rule) — sparse bodies are densified
//!   and mask-target-reconstructed at fold — and canonicalizes by client
//!   id at `finish`, which restores arrival-order independence.
//!
//! The inner fold is the aggregation hot path; the criterion bench
//! `aggregation` tracks it, including streaming-vs-barrier and the
//! sparse-vs-dense fold across masking rates.

use crate::fl::masking::MaskTarget;
use crate::runtime::manifest::LayerInfo;
use crate::util::error::{Error, Result};

/// One client's contribution to a round, as a dense vector (the wire body
/// for dense encodings; tests and the barrier reference also build these).
#[derive(Debug, Clone)]
pub struct Contribution<'a> {
    /// Originating client id (from the wire header; canonical sort key for
    /// buffering aggregators).
    pub client: usize,
    pub params: &'a [f32],
    /// Local training-sample count n_i (the FedAvg weight).
    pub n_samples: u32,
}

/// One client's contribution as a sparse wire body: `values[k]` lives at
/// coordinate `indices[k]` of a p-length vector whose other entries are
/// zero on the wire. Indices must be strictly increasing and in `[0, p)` —
/// the codec guarantees this on decode, and every fold re-checks it (a
/// duplicate index would double-count into the accumulator).
#[derive(Debug, Clone)]
pub struct SparseContribution<'a> {
    pub client: usize,
    /// Full model dimension the indices address into.
    pub p: usize,
    pub indices: &'a [u32],
    pub values: &'a [f32],
    pub n_samples: u32,
}

/// Streaming, order-insensitive aggregation: fold decoded updates as they
/// arrive, then finish into the next global model.
///
/// `Send` because tree aggregation moves shard-local partials onto worker
/// threads and back; both implementations are plain owned data.
pub trait Aggregator: Send {
    /// Fold one client's dense-bodied update into the running aggregate.
    fn fold(&mut self, contrib: Contribution<'_>) -> Result<()>;

    /// Fold one client's sparse-bodied update — O(nnz) for
    /// [`StreamingFedAvg`], no densification.
    fn fold_sparse(&mut self, contrib: SparseContribution<'_>) -> Result<()>;

    /// Number of contributions folded so far.
    fn folded(&self) -> usize;

    /// Heap bytes currently held by the aggregation state (the benchmark's
    /// O(p)-vs-O(k*p) memory evidence).
    fn state_bytes(&self) -> usize;

    /// Absorb another partial of the *same* kind and configuration, as if
    /// every contribution folded into `other` had been folded into `self`.
    ///
    /// For [`StreamingFedAvg`] this is exact by construction: the state is
    /// integer sums (`acc`, `sent`, `total_samples`), and integer addition
    /// is associative and commutative, so **any** partition of a cohort
    /// into shard-local partials merges to a bitwise-identical result —
    /// the invariant tree aggregation rests on (pinned by property tests
    /// across shard counts, including empty shards). An empty partial
    /// (zero folds) is a legal operand on either side and merges as the
    /// identity. Mismatched kinds or configurations (different `p`,
    /// different delta baseline, different attentive temperature) are
    /// typed errors.
    fn merge(&mut self, other: Box<dyn Aggregator>) -> Result<()>;

    /// Downcast hook for [`Aggregator::merge`]: a trait object cannot be
    /// matched on its concrete type, so `merge` recovers it through `Any`.
    fn into_any(self: Box<Self>) -> Box<dyn std::any::Any>;

    /// Consume the aggregator and produce the new global model.
    fn finish(self: Box<Self>) -> Result<Vec<f32>>;
}

/// Build the configured aggregator for one round. `mask_target` decides how
/// a masked-away (zero-on-the-wire) coordinate aggregates: as a literal
/// zero (`Weights`) or as the broadcast baseline value (`Delta`); the
/// aggregator owns that reconstruction now, so the server's hot loop never
/// materializes a dense vector per contribution.
pub fn make_aggregator(
    kind: crate::config::experiment::AggregatorKind,
    mask_target: MaskTarget,
    global: &[f32],
    layers: &[LayerInfo],
) -> Result<Box<dyn Aggregator>> {
    Ok(match kind {
        crate::config::experiment::AggregatorKind::FedAvg => match mask_target {
            MaskTarget::Weights => Box::new(StreamingFedAvg::new(global.len())),
            MaskTarget::Delta => Box::new(StreamingFedAvg::with_delta_baseline(global, layers)?),
        },
        crate::config::experiment::AggregatorKind::Attentive { temp } => {
            Box::new(BufferingAttentive::new(global, layers, temp, mask_target))
        }
    })
}

/// Fixed-point scale of the streaming FedAvg accumulator: products
/// `n_i * v_ij` are rounded to multiples of 2^-64 before the (integer,
/// therefore order-independent) accumulation.
const FIXED_POINT_SCALE: f64 = 18_446_744_073_709_551_616.0; // 2^64

/// Weighted products must stay inside the fixed-point grid
/// (|n_i * v| < 2^62 per coordinate): beyond it the float->int cast
/// would saturate silently — that magnitude only means a diverged
/// client, which must fail loudly.
const GRID_LIMIT: f64 = 4.611_686_018_427_387_9e18; // 2^62

/// A diverged client's update (NaN/inf) must fail loudly in every
/// aggregator — the FedAvg float->int cast would silently zero NaN and
/// the attentive softmax would propagate it into the whole global model.
fn check_finite(client: usize, values: &[f32]) -> Result<()> {
    if values.iter().any(|v| !v.is_finite()) {
        return Err(Error::invalid(format!("non-finite update from client {client}")));
    }
    Ok(())
}

/// Validate a sparse contribution's shape: index/value arity, strictly
/// increasing indices (rejects duplicates), all indices inside `[0, p)`.
fn check_sparse_shape(contrib: &SparseContribution<'_>) -> Result<()> {
    if contrib.indices.len() != contrib.values.len() {
        return Err(Error::invalid("sparse contribution index/value length mismatch"));
    }
    let mut next_min = 0u64;
    for &idx in contrib.indices {
        if (idx as u64) < next_min || idx as usize >= contrib.p {
            return Err(Error::invalid(format!(
                "sparse index {idx} from client {} out of range or out of order",
                contrib.client
            )));
        }
        next_min = idx as u64 + 1;
    }
    Ok(())
}

/// Fold one weighted value onto the fixed-point grid.
#[inline]
fn add_product(slot: &mut i128, n: f64, v: f32, client: usize) -> Result<()> {
    let x = n * v as f64;
    if x.abs() >= GRID_LIMIT {
        return Err(Error::invalid(format!(
            "update magnitude from client {client} exceeds the aggregation range"
        )));
    }
    *slot = slot
        .checked_add((x * FIXED_POINT_SCALE).round() as i128)
        .ok_or_else(|| Error::invalid("aggregation accumulator overflow"))?;
    Ok(())
}

/// [`MaskTarget::Delta`] baseline state: lets unsent masked coordinates
/// aggregate as the broadcast value without any per-contribution O(p) work.
struct DeltaBaseline {
    /// `round(b_j * 2^64)`: the broadcast pre-rounded onto the accumulator
    /// grid, so each client's baseline term is the exact integer product
    /// `n_i * grid[j]` and the cohort's sum distributes to
    /// `(total - sent[j]) * grid[j]`.
    grid: Vec<i128>,
    /// Per masked coordinate, the total sample weight of clients whose wire
    /// carried a non-zero value there (everyone else reverts to baseline).
    sent: Vec<u64>,
    /// Flattened layer table: which coordinates masking applies to.
    masked: Vec<bool>,
}

/// Sample-weighted FedAvg with O(p) state and arrival-order-independent
/// accumulation (see the module doc for the fixed-point argument).
pub struct StreamingFedAvg {
    /// Per-parameter weighted numerator `sum_i n_i * v_ij`, fixed point.
    acc: Vec<i128>,
    /// `Some` under [`MaskTarget::Delta`]; `None` aggregates wire zeros as
    /// literal zeros ([`MaskTarget::Weights`]).
    delta: Option<DeltaBaseline>,
    total_samples: u64,
    folded: usize,
}

impl StreamingFedAvg {
    /// Paper-literal aggregation: wire zeros are zeros.
    pub fn new(p: usize) -> StreamingFedAvg {
        StreamingFedAvg {
            acc: vec![0i128; p],
            delta: None,
            total_samples: 0,
            folded: 0,
        }
    }

    /// [`MaskTarget::Delta`] aggregation: a masked coordinate a client did
    /// not send reverts to `broadcast[j]` in that client's contribution.
    /// O(p) once per round here; every fold thereafter is O(nnz).
    pub fn with_delta_baseline(broadcast: &[f32], layers: &[LayerInfo]) -> Result<StreamingFedAvg> {
        let p = broadcast.len();
        let mut grid = Vec::with_capacity(p);
        for &b in broadcast {
            if !b.is_finite() || (b as f64).abs() >= GRID_LIMIT {
                return Err(Error::invalid("broadcast baseline outside the aggregation range"));
            }
            grid.push((b as f64 * FIXED_POINT_SCALE).round() as i128);
        }
        let mut masked = vec![false; p];
        for l in layers {
            if l.offset + l.size > p {
                return Err(Error::invalid(format!(
                    "layer '{}' exceeds model dimension {p}",
                    l.name
                )));
            }
            if l.masked {
                masked[l.offset..l.offset + l.size].fill(true);
            }
        }
        Ok(StreamingFedAvg {
            acc: vec![0i128; p],
            delta: Some(DeltaBaseline { grid, sent: vec![0u64; p], masked }),
            total_samples: 0,
            folded: 0,
        })
    }
}

impl Aggregator for StreamingFedAvg {
    fn fold(&mut self, contrib: Contribution<'_>) -> Result<()> {
        if contrib.params.len() != self.acc.len() {
            return Err(Error::invalid("contribution length mismatch"));
        }
        check_finite(contrib.client, contrib.params)?;
        let n = contrib.n_samples as f64;
        match &mut self.delta {
            None => {
                // skipping zeros adds the same integers as folding them:
                // round(n * 0 * S) == 0
                for (slot, &v) in self.acc.iter_mut().zip(contrib.params) {
                    if v != 0.0 {
                        add_product(slot, n, v, contrib.client)?;
                    }
                }
            }
            Some(d) => {
                for (j, &v) in contrib.params.iter().enumerate() {
                    if v != 0.0 {
                        add_product(&mut self.acc[j], n, v, contrib.client)?;
                        if d.masked[j] {
                            d.sent[j] += contrib.n_samples as u64;
                        }
                    }
                }
            }
        }
        self.total_samples += contrib.n_samples as u64;
        self.folded += 1;
        Ok(())
    }

    fn fold_sparse(&mut self, contrib: SparseContribution<'_>) -> Result<()> {
        if contrib.p != self.acc.len() {
            return Err(Error::invalid("contribution length mismatch"));
        }
        check_sparse_shape(&contrib)?;
        check_finite(contrib.client, contrib.values)?;
        let n = contrib.n_samples as f64;
        match &mut self.delta {
            None => {
                for (&idx, &v) in contrib.indices.iter().zip(contrib.values) {
                    // q8 can dequantize an entry to exactly 0.0; skip it just
                    // like the dense path so both folds add identical terms
                    if v != 0.0 {
                        add_product(&mut self.acc[idx as usize], n, v, contrib.client)?;
                    }
                }
            }
            Some(d) => {
                for (&idx, &v) in contrib.indices.iter().zip(contrib.values) {
                    let j = idx as usize;
                    if v != 0.0 {
                        add_product(&mut self.acc[j], n, v, contrib.client)?;
                        if d.masked[j] {
                            d.sent[j] += contrib.n_samples as u64;
                        }
                    }
                }
            }
        }
        self.total_samples += contrib.n_samples as u64;
        self.folded += 1;
        Ok(())
    }

    fn folded(&self) -> usize {
        self.folded
    }

    fn state_bytes(&self) -> usize {
        let base = self.acc.capacity() * std::mem::size_of::<i128>();
        match &self.delta {
            None => base,
            Some(d) => {
                base + d.grid.capacity() * std::mem::size_of::<i128>()
                    + d.sent.capacity() * std::mem::size_of::<u64>()
                    + d.masked.capacity()
            }
        }
    }

    fn merge(&mut self, other: Box<dyn Aggregator>) -> Result<()> {
        let other = other
            .into_any()
            .downcast::<StreamingFedAvg>()
            .map_err(|_| Error::invalid("cannot merge aggregator partials of different kinds"))?;
        if other.acc.len() != self.acc.len() {
            return Err(Error::invalid("cannot merge partials of different model dimension"));
        }
        match (&mut self.delta, &other.delta) {
            (None, None) => {}
            (Some(a), Some(b)) => {
                // the baseline is per-round state shared by every shard:
                // partials built from different broadcasts are a bug
                if a.grid != b.grid || a.masked != b.masked {
                    return Err(Error::invalid(
                        "cannot merge partials with different delta baselines",
                    ));
                }
                for (s, &o) in a.sent.iter_mut().zip(&b.sent) {
                    *s = s
                        .checked_add(o)
                        .ok_or_else(|| Error::invalid("aggregation sent-weight overflow"))?;
                }
            }
            _ => {
                return Err(Error::invalid(
                    "cannot merge a delta-baseline partial with a weights-target partial",
                ))
            }
        }
        for (s, &o) in self.acc.iter_mut().zip(&other.acc) {
            *s = s
                .checked_add(o)
                .ok_or_else(|| Error::invalid("aggregation accumulator overflow"))?;
        }
        self.total_samples = self
            .total_samples
            .checked_add(other.total_samples)
            .ok_or_else(|| Error::invalid("aggregation sample-count overflow"))?;
        self.folded += other.folded;
        Ok(())
    }

    fn into_any(self: Box<Self>) -> Box<dyn std::any::Any> {
        self
    }

    fn finish(self: Box<Self>) -> Result<Vec<f32>> {
        if self.folded == 0 {
            return Err(Error::invalid("cannot aggregate zero contributions"));
        }
        if self.total_samples == 0 {
            return Err(Error::invalid("total sample count is zero"));
        }
        let total = self.total_samples as f64;
        match &self.delta {
            None => Ok(self
                .acc
                .iter()
                .map(|&a| ((a as f64 / FIXED_POINT_SCALE) / total) as f32)
                .collect()),
            Some(d) => {
                // the one O(p) pass: fold the cohort's collapsed baseline
                // mass (total - sent[j]) * grid[j] into each masked slot
                let mut out = Vec::with_capacity(self.acc.len());
                for (j, &a) in self.acc.iter().enumerate() {
                    let num = if d.masked[j] {
                        let missing = self
                            .total_samples
                            .checked_sub(d.sent[j])
                            .ok_or_else(|| {
                                Error::invalid("sent weight exceeds total samples (duplicate sparse indices?)")
                            })? as i128;
                        a.checked_add(
                            missing
                                .checked_mul(d.grid[j])
                                .ok_or_else(|| Error::invalid("aggregation accumulator overflow"))?,
                        )
                        .ok_or_else(|| Error::invalid("aggregation accumulator overflow"))?
                    } else {
                        a
                    };
                    out.push(((num as f64 / FIXED_POINT_SCALE) / total) as f32);
                }
                Ok(out)
            }
        }
    }
}

/// Attentive aggregation as an [`Aggregator`]: buffers decoded updates
/// (O(k*p) — the rule needs every client's distance before any weight is
/// known), reconstructing each wire body to its dense mask-target form at
/// fold, and sorts by client id at finish so the result does not depend on
/// arrival order.
pub struct BufferingAttentive {
    global: Vec<f32>,
    layers: Vec<LayerInfo>,
    temp: f64,
    mask_target: MaskTarget,
    buffered: Vec<(usize, u32, Vec<f32>)>,
}

impl BufferingAttentive {
    pub fn new(
        global: &[f32],
        layers: &[LayerInfo],
        temp: f64,
        mask_target: MaskTarget,
    ) -> BufferingAttentive {
        BufferingAttentive {
            global: global.to_vec(),
            layers: layers.to_vec(),
            temp,
            mask_target,
            buffered: Vec::new(),
        }
    }

    /// In-place mask-target reconstruction of a wire vector: under `Delta`,
    /// masked-layer zeros revert to the broadcast value (the dense-vector
    /// equivalent of [`crate::fl::masking::apply_delta_target`]).
    fn reconstruct(&self, dense: &mut [f32]) {
        if self.mask_target == MaskTarget::Weights {
            return;
        }
        for l in &self.layers {
            if !l.masked {
                continue;
            }
            for i in l.offset..l.offset + l.size {
                if dense[i] == 0.0 {
                    dense[i] = self.global[i];
                }
            }
        }
    }
}

impl Aggregator for BufferingAttentive {
    fn fold(&mut self, contrib: Contribution<'_>) -> Result<()> {
        if contrib.params.len() != self.global.len() {
            return Err(Error::invalid("contribution length mismatch"));
        }
        check_finite(contrib.client, contrib.params)?;
        let mut dense = contrib.params.to_vec();
        self.reconstruct(&mut dense);
        self.buffered.push((contrib.client, contrib.n_samples, dense));
        Ok(())
    }

    fn fold_sparse(&mut self, contrib: SparseContribution<'_>) -> Result<()> {
        if contrib.p != self.global.len() {
            return Err(Error::invalid("contribution length mismatch"));
        }
        check_sparse_shape(&contrib)?;
        check_finite(contrib.client, contrib.values)?;
        let mut dense = vec![0.0f32; contrib.p];
        for (&idx, &v) in contrib.indices.iter().zip(contrib.values) {
            dense[idx as usize] = v;
        }
        self.reconstruct(&mut dense);
        self.buffered.push((contrib.client, contrib.n_samples, dense));
        Ok(())
    }

    fn folded(&self) -> usize {
        self.buffered.len()
    }

    fn state_bytes(&self) -> usize {
        self.global.capacity() * 4
            + self
                .buffered
                .iter()
                .map(|(_, _, v)| v.capacity() * 4)
                .sum::<usize>()
    }

    fn merge(&mut self, other: Box<dyn Aggregator>) -> Result<()> {
        let other = other
            .into_any()
            .downcast::<BufferingAttentive>()
            .map_err(|_| Error::invalid("cannot merge aggregator partials of different kinds"))?;
        if other.global != self.global
            || other.layers != self.layers
            || other.temp != self.temp
            || other.mask_target != self.mask_target
        {
            return Err(Error::invalid(
                "cannot merge attentive partials with different configurations",
            ));
        }
        // finish() sorts by client id, so concatenation order is immaterial
        self.buffered.extend(other.buffered);
        Ok(())
    }

    fn into_any(self: Box<Self>) -> Box<dyn std::any::Any> {
        self
    }

    fn finish(mut self: Box<Self>) -> Result<Vec<f32>> {
        self.buffered.sort_by_key(|(client, _, _)| *client);
        let contribs: Vec<Contribution> = self
            .buffered
            .iter()
            .map(|(client, n_samples, params)| Contribution {
                client: *client,
                params,
                n_samples: *n_samples,
            })
            .collect();
        attentive_mean(&self.global, &contribs, &self.layers, self.temp)
    }
}

/// Barrier-style sample-weighted mean: folds `contribs` through
/// [`StreamingFedAvg`] in the given order and finishes. Because the fold is
/// order-independent, this is the reference the streamed server path is
/// asserted bit-identical against.
pub fn weighted_mean(contribs: &[Contribution]) -> Result<Vec<f32>> {
    if contribs.is_empty() {
        return Err(Error::invalid("cannot aggregate zero contributions"));
    }
    let mut agg = StreamingFedAvg::new(contribs[0].params.len());
    for c in contribs {
        agg.fold(c.clone())?;
    }
    Box::new(agg).finish()
}

/// Unweighted mean (Eq. 1) — kept for the uniform-shard fast path and the
/// ablation bench comparing the two rules.
pub fn uniform_mean(contribs: &[Contribution]) -> Result<Vec<f32>> {
    if contribs.is_empty() {
        return Err(Error::invalid("cannot aggregate zero contributions"));
    }
    let p = contribs[0].params.len();
    if contribs.iter().any(|c| c.params.len() != p) {
        return Err(Error::invalid("contribution length mismatch"));
    }
    let w = 1.0f64 / contribs.len() as f64;
    let mut acc = vec![0.0f64; p];
    for c in contribs {
        for (slot, &v) in acc.iter_mut().zip(c.params) {
            *slot += w * v as f64;
        }
    }
    Ok(acc.into_iter().map(|v| v as f32).collect())
}

/// Attentive aggregation (Ji et al. [11], the paper's cited improvement to
/// vanilla FedAvg): per layer, clients whose update stays closer to the
/// current global model get larger softmax weights,
/// `a_i = softmax(-d_i / (T * mean(d)))` with `d_i = ||Theta_i^l - Theta^l||_2`.
/// Normalizing by the mean distance makes the temperature `temp`
/// scale-free. Exposed as `aggregator = "attentive"` in the config and in
/// the ablation driver; downweights divergent/outlier clients.
pub fn attentive_mean(
    global: &[f32],
    contribs: &[Contribution],
    layers: &[LayerInfo],
    temp: f64,
) -> Result<Vec<f32>> {
    if contribs.is_empty() {
        return Err(Error::invalid("cannot aggregate zero contributions"));
    }
    if contribs.iter().any(|c| c.params.len() != global.len()) {
        return Err(Error::invalid("contribution length mismatch"));
    }
    if !(temp > 0.0) {
        return Err(Error::invalid("temperature must be positive"));
    }
    let mut out = vec![0.0f32; global.len()];
    for l in layers {
        let seg = l.offset..l.offset + l.size;
        // per-client L2 distance to the global layer
        let dists: Vec<f64> = contribs
            .iter()
            .map(|c| {
                c.params[seg.clone()]
                    .iter()
                    .zip(&global[seg.clone()])
                    .map(|(a, b)| ((a - b) as f64).powi(2))
                    .sum::<f64>()
                    .sqrt()
            })
            .collect();
        let mean_d = dists.iter().sum::<f64>() / dists.len() as f64;
        let scale = if mean_d > 0.0 { temp * mean_d } else { 1.0 };
        let logits: Vec<f64> = dists.iter().map(|d| -d / scale).collect();
        let max_logit = logits.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        let exps: Vec<f64> = logits.iter().map(|z| (z - max_logit).exp()).collect();
        let z: f64 = exps.iter().sum();
        for (c, w) in contribs.iter().zip(exps.iter().map(|e| e / z)) {
            for (slot, &v) in out[seg.clone()].iter_mut().zip(&c.params[seg.clone()]) {
                *slot += (w * v as f64) as f32;
            }
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::check;

    fn one_layer(size: usize) -> Vec<LayerInfo> {
        vec![LayerInfo {
            name: "w".into(),
            shape: vec![size],
            offset: 0,
            size,
            masked: true,
        }]
    }

    fn contrib(client: usize, params: &[f32], n_samples: u32) -> Contribution<'_> {
        Contribution {
            client,
            params,
            n_samples,
        }
    }

    /// Sparse view of a dense vector (the non-zero entries, ascending).
    fn sparsify(v: &[f32]) -> (Vec<u32>, Vec<f32>) {
        let mut idx = Vec::new();
        let mut val = Vec::new();
        for (i, &x) in v.iter().enumerate() {
            if x != 0.0 {
                idx.push(i as u32);
                val.push(x);
            }
        }
        (idx, val)
    }

    #[test]
    fn attentive_equal_contribs_is_identity() {
        let global = vec![0.0f32; 8];
        let a = vec![1.0f32; 8];
        let contribs = vec![contrib(0, &a, 1), contrib(1, &a, 1)];
        let out = attentive_mean(&global, &contribs, &one_layer(8), 1.0).unwrap();
        for v in out {
            assert!((v - 1.0).abs() < 1e-6);
        }
    }

    #[test]
    fn attentive_downweights_outlier() {
        let global = vec![0.0f32; 16];
        let near: Vec<f32> = vec![0.1; 16];
        let far: Vec<f32> = vec![10.0; 16];
        let contribs = vec![contrib(0, &near, 1), contrib(1, &near, 1), contrib(2, &far, 1)];
        let attn = attentive_mean(&global, &contribs, &one_layer(16), 0.5).unwrap();
        let plain = uniform_mean(&contribs).unwrap();
        assert!(
            attn[0] < plain[0],
            "attentive {} should pull toward the near majority vs mean {}",
            attn[0],
            plain[0]
        );
    }

    #[test]
    fn attentive_rejects_bad_inputs() {
        let global = vec![0.0f32; 4];
        assert!(attentive_mean(&global, &[], &one_layer(4), 1.0).is_err());
        let a = vec![1.0f32; 4];
        let c = vec![contrib(0, &a, 1)];
        assert!(attentive_mean(&global, &c, &one_layer(4), 0.0).is_err());
    }

    #[test]
    fn equal_weights_reduce_to_plain_mean() {
        let a = vec![1.0f32, 2.0, 3.0];
        let b = vec![3.0f32, 4.0, 5.0];
        let out = weighted_mean(&[contrib(0, &a, 10), contrib(1, &b, 10)]).unwrap();
        assert_eq!(out, vec![2.0, 3.0, 4.0]);
    }

    #[test]
    fn weights_follow_sample_counts() {
        let a = vec![0.0f32];
        let b = vec![4.0f32];
        let out = weighted_mean(&[contrib(0, &a, 3), contrib(1, &b, 1)]).unwrap();
        assert!((out[0] - 1.0).abs() < 1e-7);
    }

    #[test]
    fn rejects_degenerate_inputs() {
        assert!(weighted_mean(&[]).is_err());
        let a = vec![1.0f32, 2.0];
        let b = vec![1.0f32];
        assert!(weighted_mean(&[contrib(0, &a, 1), contrib(1, &b, 1)]).is_err());
        assert!(weighted_mean(&[contrib(0, &a, 0)]).is_err());
    }

    #[test]
    fn diverged_client_fails_loudly_instead_of_zeroing() {
        let nan = vec![1.0f32, f32::NAN];
        let inf = vec![f32::INFINITY, 0.0];
        // finite but beyond the fixed-point grid: saturating would corrupt
        let huge = vec![1e25f32, 0.0];
        assert!(weighted_mean(&[contrib(3, &nan, 1)]).is_err());
        let mut agg = StreamingFedAvg::new(2);
        assert!(agg.fold(contrib(3, &inf, 1)).is_err());
        assert_eq!(agg.folded(), 0);
        let mut agg = StreamingFedAvg::new(2);
        assert!(agg.fold(contrib(3, &huge, 500)).is_err());
        // the sparse fold enforces the same invariants
        let mut agg = StreamingFedAvg::new(2);
        assert!(agg
            .fold_sparse(SparseContribution {
                client: 3,
                p: 2,
                indices: &[1],
                values: &[f32::NAN],
                n_samples: 1,
            })
            .is_err());
        assert_eq!(agg.folded(), 0);
        // the attentive buffer enforces the same invariant
        let mut attn =
            BufferingAttentive::new(&[0.0f32, 0.0], &one_layer(2), 1.0, MaskTarget::Weights);
        assert!(attn.fold(contrib(3, &nan, 1)).is_err());
        assert_eq!(attn.folded(), 0);
    }

    #[test]
    fn sparse_fold_rejects_malformed_indices() {
        // out of range
        let mut agg = StreamingFedAvg::new(4);
        let res = agg.fold_sparse(SparseContribution {
            client: 0,
            p: 4,
            indices: &[4],
            values: &[1.0],
            n_samples: 1,
        });
        assert!(res.is_err());
        // duplicate: would double-count (and disagree with a buffering
        // aggregator's last-write-wins scatter) — both impls reject it
        let dup = |p: usize| SparseContribution {
            client: 0,
            p,
            indices: &[2, 2],
            values: &[1.0, 1.0],
            n_samples: 1,
        };
        let mut agg = StreamingFedAvg::new(4);
        assert!(agg.fold_sparse(dup(4)).is_err());
        assert_eq!(agg.folded(), 0);
        let mut attn =
            BufferingAttentive::new(&[0.0f32; 4], &one_layer(4), 1.0, MaskTarget::Weights);
        assert!(attn.fold_sparse(dup(4)).is_err());
        // out of order
        let mut agg = StreamingFedAvg::new(4);
        assert!(agg
            .fold_sparse(SparseContribution {
                client: 0,
                p: 4,
                indices: &[3, 1],
                values: &[1.0, 1.0],
                n_samples: 1,
            })
            .is_err());
    }

    #[test]
    fn single_contribution_is_identity() {
        let a = vec![1.5f32, -2.5, 0.0];
        let out = weighted_mean(&[contrib(0, &a, 7)]).unwrap();
        assert_eq!(out, a);
    }

    #[test]
    fn prop_mean_within_value_envelope() {
        check("aggregate envelope", 80, |g| {
            let p = g.usize_in(1, 300);
            let k = g.usize_in(1, 8);
            let vecs: Vec<Vec<f32>> = (0..k).map(|_| g.normal_vec(p)).collect();
            let contribs: Vec<Contribution> = vecs
                .iter()
                .enumerate()
                .map(|(i, v)| contrib(i, v, 1 + (g.seed % 100) as u32))
                .collect();
            let out = weighted_mean(&contribs).unwrap();
            for j in 0..p {
                let lo = vecs.iter().map(|v| v[j]).fold(f32::INFINITY, f32::min);
                let hi = vecs.iter().map(|v| v[j]).fold(f32::NEG_INFINITY, f32::max);
                assert!(out[j] >= lo - 1e-5 && out[j] <= hi + 1e-5);
            }
        });
    }

    #[test]
    fn prop_uniform_equals_weighted_when_counts_equal() {
        check("uniform == weighted under equal counts", 50, |g| {
            let p = g.usize_in(1, 200);
            let k = g.usize_in(1, 6);
            let vecs: Vec<Vec<f32>> = (0..k).map(|_| g.normal_vec(p)).collect();
            let cs: Vec<Contribution> =
                vecs.iter().enumerate().map(|(i, v)| contrib(i, v, 42)).collect();
            let a = weighted_mean(&cs).unwrap();
            let b = uniform_mean(&cs).unwrap();
            for (x, y) in a.iter().zip(&b) {
                assert!((x - y).abs() < 1e-6);
            }
        });
    }

    #[test]
    fn masked_zeros_dilute_the_mean() {
        // paper-literal semantics: a masked (zero) entry pulls the average
        // toward zero rather than being skipped
        let a = vec![2.0f32];
        let b = vec![0.0f32]; // masked out at this position
        let out = weighted_mean(&[contrib(0, &a, 1), contrib(1, &b, 1)]).unwrap();
        assert_eq!(out[0], 1.0);
    }

    #[test]
    fn sparse_fold_is_bitwise_identical_to_dense_fold() {
        check("sparse == dense fold (weights)", 60, |g| {
            let p = g.usize_in(1, 400);
            let k = g.usize_in(1, 8);
            let mut dense_agg = StreamingFedAvg::new(p);
            let mut sparse_agg = StreamingFedAvg::new(p);
            for i in 0..k {
                let density = g.f32_in(0.0, 0.8);
                let v: Vec<f32> = (0..p)
                    .map(|_| if g.f32_in(0.0, 1.0) < density { g.f32_in(-2.0, 2.0) } else { 0.0 })
                    .collect();
                let w = g.usize_in(1, 900) as u32;
                dense_agg.fold(contrib(i, &v, w)).unwrap();
                let (idx, val) = sparsify(&v);
                sparse_agg
                    .fold_sparse(SparseContribution {
                        client: i,
                        p,
                        indices: &idx,
                        values: &val,
                        n_samples: w,
                    })
                    .unwrap();
            }
            let a = Box::new(dense_agg).finish().unwrap();
            let b = Box::new(sparse_agg).finish().unwrap();
            assert_eq!(a, b, "seed {:#x}", g.seed);
        });
    }

    #[test]
    fn delta_baseline_all_zero_upload_reverts_to_broadcast_exactly() {
        let mut g = crate::util::prop::Gen::new(0xde17a);
        let p = 64;
        let broadcast: Vec<f32> = (0..p).map(|_| g.f32_in(-2.0, 2.0)).collect();
        let layers = one_layer(p);
        let mut agg = StreamingFedAvg::with_delta_baseline(&broadcast, &layers).unwrap();
        // a client that masked everything away: empty sparse body
        agg.fold_sparse(SparseContribution {
            client: 0,
            p,
            indices: &[],
            values: &[],
            n_samples: 5,
        })
        .unwrap();
        let out = Box::new(agg).finish().unwrap();
        assert_eq!(out, broadcast, "unsent coordinates must aggregate as the broadcast");
    }

    #[test]
    fn delta_baseline_mixes_sent_and_unsent_weights() {
        // two clients over one coordinate: client 0 (n=3) sends 4.0,
        // client 1 (n=1) sends nothing -> (3*4 + 1*b) / 4 with b = 2.0
        let broadcast = vec![2.0f32];
        let layers = one_layer(1);
        let mut agg = StreamingFedAvg::with_delta_baseline(&broadcast, &layers).unwrap();
        agg.fold_sparse(SparseContribution {
            client: 0,
            p: 1,
            indices: &[0],
            values: &[4.0],
            n_samples: 3,
        })
        .unwrap();
        agg.fold_sparse(SparseContribution {
            client: 1,
            p: 1,
            indices: &[],
            values: &[],
            n_samples: 1,
        })
        .unwrap();
        let out = Box::new(agg).finish().unwrap();
        assert!((out[0] - 3.5).abs() < 1e-6, "got {}", out[0]);
    }

    #[test]
    fn delta_baseline_ignores_unmasked_layers() {
        // layer 0 masked, layer 1 not: zeros in the unmasked layer stay
        // zeros (a true zero, not a masked-away coordinate)
        let layers = vec![
            LayerInfo { name: "w".into(), shape: vec![2], offset: 0, size: 2, masked: true },
            LayerInfo { name: "b".into(), shape: vec![2], offset: 2, size: 2, masked: false },
        ];
        let broadcast = vec![1.0f32, 2.0, 3.0, 4.0];
        let mut agg = StreamingFedAvg::with_delta_baseline(&broadcast, &layers).unwrap();
        agg.fold(contrib(0, &[5.0, 0.0, 0.0, 6.0], 2)).unwrap();
        let out = Box::new(agg).finish().unwrap();
        assert_eq!(out, vec![5.0, 2.0, 0.0, 6.0]);
    }

    #[test]
    fn streaming_fold_is_arrival_order_independent_bitwise() {
        check("streaming order independence", 60, |g| {
            let p = g.usize_in(1, 300);
            let k = g.usize_in(2, 10);
            let vecs: Vec<Vec<f32>> = (0..k).map(|_| g.normal_vec(p)).collect();
            let weights: Vec<u32> = (0..k).map(|_| g.usize_in(1, 1000) as u32).collect();
            let contribs: Vec<Contribution> = vecs
                .iter()
                .zip(&weights)
                .enumerate()
                .map(|(i, (v, &w))| contrib(i, v, w))
                .collect();
            let barrier = weighted_mean(&contribs).unwrap();
            // shuffled arrival order
            let mut order: Vec<usize> = (0..k).collect();
            let mut rng = crate::sim::rng::Rng::new(g.seed ^ 0x0bd3b);
            rng.shuffle(&mut order);
            let mut agg = StreamingFedAvg::new(p);
            for &i in &order {
                agg.fold(contribs[i].clone()).unwrap();
            }
            let streamed = Box::new(agg).finish().unwrap();
            assert_eq!(streamed, barrier, "arrival order changed the aggregate");
        });
    }

    #[test]
    fn streaming_state_is_o_p_independent_of_cohort_size() {
        let p = 512;
        let v = vec![1.0f32; p];
        let mut state_sizes = Vec::new();
        for k in [1usize, 8, 64] {
            let mut agg = StreamingFedAvg::new(p);
            for i in 0..k {
                agg.fold(contrib(i, &v, 10)).unwrap();
            }
            assert_eq!(agg.folded(), k);
            state_sizes.push(agg.state_bytes());
        }
        assert_eq!(state_sizes[0], state_sizes[1]);
        assert_eq!(state_sizes[1], state_sizes[2]);
        // the delta baseline adds O(p) state but stays k-independent too
        let broadcast = vec![0.5f32; p];
        let layers = one_layer(p);
        let mut delta_sizes = Vec::new();
        for k in [2usize, 32] {
            let mut agg = StreamingFedAvg::with_delta_baseline(&broadcast, &layers).unwrap();
            for i in 0..k {
                agg.fold(contrib(i, &v, 10)).unwrap();
            }
            delta_sizes.push(agg.state_bytes());
        }
        assert_eq!(delta_sizes[0], delta_sizes[1]);
        // while a buffering aggregator grows linearly in k
        let global = vec![0.0f32; p];
        let mut small = BufferingAttentive::new(&global, &layers, 1.0, MaskTarget::Weights);
        let mut big = BufferingAttentive::new(&global, &layers, 1.0, MaskTarget::Weights);
        for i in 0..2 {
            small.fold(contrib(i, &v, 10)).unwrap();
        }
        for i in 0..16 {
            big.fold(contrib(i, &v, 10)).unwrap();
        }
        assert!(big.state_bytes() > small.state_bytes());
    }

    #[test]
    fn buffering_attentive_matches_barrier_attentive_any_order() {
        let p = 32;
        let layers = one_layer(p);
        let global = vec![0.0f32; p];
        let mut g = crate::util::prop::Gen::new(11);
        let vecs: Vec<Vec<f32>> = (0..5).map(|_| g.normal_vec(p)).collect();
        let contribs: Vec<Contribution> =
            vecs.iter().enumerate().map(|(i, v)| contrib(i, v, 7)).collect();
        let barrier = attentive_mean(&global, &contribs, &layers, 0.8).unwrap();
        for order in [[4usize, 2, 0, 3, 1], [1, 3, 0, 2, 4]] {
            let mut agg = BufferingAttentive::new(&global, &layers, 0.8, MaskTarget::Weights);
            for &i in &order {
                agg.fold(contribs[i].clone()).unwrap();
            }
            let streamed = Box::new(agg).finish().unwrap();
            assert_eq!(streamed, barrier, "order {order:?} changed attentive result");
        }
    }

    #[test]
    fn attentive_sparse_fold_densifies_and_reconstructs() {
        let p = 4;
        let layers = one_layer(p);
        let global = vec![1.0f32, 2.0, 3.0, 4.0];
        // Delta target: unsent coordinates revert to the broadcast, so a
        // sparse body {0: 9.0} must buffer as [9, 2, 3, 4]
        let mut agg = BufferingAttentive::new(&global, &layers, 1.0, MaskTarget::Delta);
        agg.fold_sparse(SparseContribution {
            client: 0,
            p,
            indices: &[0],
            values: &[9.0],
            n_samples: 1,
        })
        .unwrap();
        let out = Box::new(agg).finish().unwrap();
        assert_eq!(out, vec![9.0, 2.0, 3.0, 4.0]);
        // Weights target: unsent coordinates stay zero
        let mut agg = BufferingAttentive::new(&global, &layers, 1.0, MaskTarget::Weights);
        agg.fold_sparse(SparseContribution {
            client: 0,
            p,
            indices: &[0],
            values: &[9.0],
            n_samples: 1,
        })
        .unwrap();
        let out = Box::new(agg).finish().unwrap();
        assert_eq!(out, vec![9.0, 0.0, 0.0, 0.0]);
    }

    #[test]
    fn prop_sharded_merge_is_bitwise_equal_to_flat_fold_for_both_targets() {
        use crate::config::experiment::AggregatorKind;
        // Any partition of a cohort into any shard assignment — including
        // empty shards and the degenerate single shard — must merge to a
        // result bitwise-identical to the single-threaded fold. This is
        // the invariant tree aggregation rests on.
        check("sharded merge == flat fold", 40, |g| {
            let p = g.usize_in(1, 300);
            let k = g.usize_in(1, 12);
            let layers = one_layer(p);
            let broadcast = g.normal_vec(p);
            let updates: Vec<(Vec<f32>, u32)> = (0..k)
                .map(|_| {
                    let density = g.f32_in(0.0, 0.9);
                    let v: Vec<f32> = (0..p)
                        .map(|_| {
                            if g.f32_in(0.0, 1.0) < density {
                                g.f32_in(-2.0, 2.0)
                            } else {
                                0.0
                            }
                        })
                        .collect();
                    (v, g.usize_in(1, 900) as u32)
                })
                .collect();
            for target in [MaskTarget::Weights, MaskTarget::Delta] {
                let mut flat =
                    make_aggregator(AggregatorKind::FedAvg, target, &broadcast, &layers).unwrap();
                for (i, (v, w)) in updates.iter().enumerate() {
                    flat.fold(contrib(i, v, *w)).unwrap();
                }
                let reference = flat.finish().unwrap();
                for shards in [1usize, 2, 8] {
                    let mut partials: Vec<Box<dyn Aggregator>> = (0..shards)
                        .map(|_| {
                            make_aggregator(AggregatorKind::FedAvg, target, &broadcast, &layers)
                                .unwrap()
                        })
                        .collect();
                    // random shard assignment: some shards may stay empty
                    for (i, (v, w)) in updates.iter().enumerate() {
                        let s = g.usize_in(0, shards - 1);
                        // mix dense and sparse folds across shards
                        if g.bool() {
                            partials[s].fold(contrib(i, v, *w)).unwrap();
                        } else {
                            let (idx, val) = sparsify(v);
                            partials[s]
                                .fold_sparse(SparseContribution {
                                    client: i,
                                    p,
                                    indices: &idx,
                                    values: &val,
                                    n_samples: *w,
                                })
                                .unwrap();
                        }
                    }
                    let mut root = partials.remove(0);
                    for partial in partials {
                        root.merge(partial).unwrap();
                    }
                    assert_eq!(root.folded(), k);
                    let merged = root.finish().unwrap();
                    assert_eq!(
                        merged, reference,
                        "shards {shards} target {target:?} seed {:#x}",
                        g.seed
                    );
                }
            }
        });
    }

    #[test]
    fn attentive_merge_concatenates_and_matches_flat() {
        let p = 24;
        let layers = one_layer(p);
        let global = vec![0.25f32; p];
        let mut g = crate::util::prop::Gen::new(0xa77e);
        let vecs: Vec<Vec<f32>> = (0..6).map(|_| g.normal_vec(p)).collect();
        let mut flat = BufferingAttentive::new(&global, &layers, 0.7, MaskTarget::Weights);
        for (i, v) in vecs.iter().enumerate() {
            flat.fold(contrib(i, v, 3)).unwrap();
        }
        let reference = Box::new(flat).finish().unwrap();
        // split 6 clients over 3 partials, one left empty
        let mut parts: Vec<BufferingAttentive> = (0..3)
            .map(|_| BufferingAttentive::new(&global, &layers, 0.7, MaskTarget::Weights))
            .collect();
        for (i, v) in vecs.iter().enumerate() {
            parts[if i < 3 { 1 } else { 2 }].fold(contrib(i, v, 3)).unwrap();
        }
        let mut root: Box<dyn Aggregator> = Box::new(parts.remove(0));
        for part in parts {
            root.merge(Box::new(part)).unwrap();
        }
        assert_eq!(root.folded(), 6);
        assert_eq!(root.finish().unwrap(), reference);
    }

    #[test]
    fn merge_rejects_mismatched_partials() {
        use crate::config::experiment::AggregatorKind;
        let layers = one_layer(4);
        let global = vec![1.0f32; 4];
        // different kinds
        let mut fedavg: Box<dyn Aggregator> = Box::new(StreamingFedAvg::new(4));
        let attn = BufferingAttentive::new(&global, &layers, 1.0, MaskTarget::Weights);
        assert!(fedavg.merge(Box::new(attn)).is_err());
        // different model dimension
        let mut a: Box<dyn Aggregator> = Box::new(StreamingFedAvg::new(4));
        assert!(a.merge(Box::new(StreamingFedAvg::new(5))).is_err());
        // delta-baseline vs weights-target
        let mut a: Box<dyn Aggregator> = Box::new(StreamingFedAvg::new(4));
        let d = StreamingFedAvg::with_delta_baseline(&global, &layers).unwrap();
        assert!(a.merge(Box::new(d)).is_err());
        // different baselines
        let mut a: Box<dyn Aggregator> =
            Box::new(StreamingFedAvg::with_delta_baseline(&global, &layers).unwrap());
        let other = StreamingFedAvg::with_delta_baseline(&[2.0f32; 4], &layers).unwrap();
        assert!(a.merge(Box::new(other)).is_err());
        // different attentive temperature
        let mut a: Box<dyn Aggregator> =
            Box::new(BufferingAttentive::new(&global, &layers, 1.0, MaskTarget::Weights));
        let other = BufferingAttentive::new(&global, &layers, 2.0, MaskTarget::Weights);
        assert!(a.merge(Box::new(other)).is_err());
        // a healthy merge with an empty partial is the identity
        let mut a =
            make_aggregator(AggregatorKind::FedAvg, MaskTarget::Weights, &global, &layers).unwrap();
        a.fold(contrib(0, &[1.0, 2.0, 3.0, 4.0], 2)).unwrap();
        let empty =
            make_aggregator(AggregatorKind::FedAvg, MaskTarget::Weights, &global, &layers).unwrap();
        a.merge(empty).unwrap();
        assert_eq!(a.folded(), 1);
        assert_eq!(a.finish().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
    }

    #[test]
    fn make_aggregator_dispatches_on_kind_and_target() {
        use crate::config::experiment::AggregatorKind;
        let global = vec![0.0f32; 16];
        let layers = one_layer(16);
        let v = vec![2.0f32; 16];
        let mut fedavg =
            make_aggregator(AggregatorKind::FedAvg, MaskTarget::Weights, &global, &layers).unwrap();
        fedavg.fold(contrib(0, &v, 5)).unwrap();
        assert_eq!(fedavg.finish().unwrap(), v);
        let mut attn = make_aggregator(
            AggregatorKind::Attentive { temp: 1.0 },
            MaskTarget::Weights,
            &global,
            &layers,
        )
        .unwrap();
        attn.fold(contrib(0, &v, 5)).unwrap();
        let out = attn.finish().unwrap();
        for x in out {
            assert!((x - 2.0).abs() < 1e-6);
        }
        // delta target wires the broadcast baseline through
        let broadcast = vec![1.0f32; 16];
        let mut delta =
            make_aggregator(AggregatorKind::FedAvg, MaskTarget::Delta, &broadcast, &layers)
                .unwrap();
        delta
            .fold_sparse(SparseContribution {
                client: 0,
                p: 16,
                indices: &[],
                values: &[],
                n_samples: 3,
            })
            .unwrap();
        assert_eq!(delta.finish().unwrap(), broadcast);
    }
}
