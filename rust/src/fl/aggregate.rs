//! Weighted federated averaging (paper §3.1).
//!
//! The aggregation rule is FedAvg's sample-weighted mean,
//! `Theta_{t+1} = sum_i (n_i / n) Theta_t^i` — Eq. 2 of the paper modulo its
//! extra `1/m` factor, which would shrink the aggregate by the cohort size
//! and contradicts both Eq. 1 and the cited McMahan et al.; DESIGN.md §4
//! records this as a presumed typo. Masked uploads are averaged exactly as
//! received (zeros included), which is the paper-literal semantics of
//! Alg. 2/4.
//!
//! The inner loop is the aggregation hot path (P-length fused
//! multiply-adds); the criterion bench `aggregation` tracks it.

use crate::util::error::{Error, Result};

/// One client's contribution to a round.
#[derive(Debug, Clone)]
pub struct Contribution<'a> {
    pub params: &'a [f32],
    /// Local training-sample count n_i (the FedAvg weight).
    pub n_samples: u32,
}

/// Sample-weighted mean of client parameter vectors.
///
/// Accumulates in f64 to keep the mean exact to f32 resolution even for
/// hundreds of clients (matters for bit-reproducibility across pool sizes:
/// summation order is fixed by client index upstream).
pub fn weighted_mean(contribs: &[Contribution]) -> Result<Vec<f32>> {
    if contribs.is_empty() {
        return Err(Error::invalid("cannot aggregate zero contributions"));
    }
    let p = contribs[0].params.len();
    if contribs.iter().any(|c| c.params.len() != p) {
        return Err(Error::invalid("contribution length mismatch"));
    }
    let total: u64 = contribs.iter().map(|c| c.n_samples as u64).sum();
    if total == 0 {
        return Err(Error::invalid("total sample count is zero"));
    }
    let mut acc = vec![0.0f64; p];
    for c in contribs {
        let w = c.n_samples as f64 / total as f64;
        for (slot, &v) in acc.iter_mut().zip(c.params) {
            *slot += w * v as f64;
        }
    }
    Ok(acc.into_iter().map(|v| v as f32).collect())
}

/// Unweighted mean (Eq. 1) — kept for the uniform-shard fast path and the
/// ablation bench comparing the two rules.
pub fn uniform_mean(contribs: &[Contribution]) -> Result<Vec<f32>> {
    if contribs.is_empty() {
        return Err(Error::invalid("cannot aggregate zero contributions"));
    }
    let p = contribs[0].params.len();
    if contribs.iter().any(|c| c.params.len() != p) {
        return Err(Error::invalid("contribution length mismatch"));
    }
    let w = 1.0f64 / contribs.len() as f64;
    let mut acc = vec![0.0f64; p];
    for c in contribs {
        for (slot, &v) in acc.iter_mut().zip(c.params) {
            *slot += w * v as f64;
        }
    }
    Ok(acc.into_iter().map(|v| v as f32).collect())
}

/// Attentive aggregation (Ji et al. [11], the paper's cited improvement to
/// vanilla FedAvg): per layer, clients whose update stays closer to the
/// current global model get larger softmax weights,
/// `a_i = softmax(-d_i / (T * mean(d)))` with `d_i = ||Theta_i^l - Theta^l||_2`.
/// Normalizing by the mean distance makes the temperature `temp`
/// scale-free. Exposed as `aggregator = "attentive"` in the config and in
/// the ablation driver; downweights divergent/outlier clients.
pub fn attentive_mean(
    global: &[f32],
    contribs: &[Contribution],
    layers: &[crate::runtime::manifest::LayerInfo],
    temp: f64,
) -> Result<Vec<f32>> {
    if contribs.is_empty() {
        return Err(Error::invalid("cannot aggregate zero contributions"));
    }
    if contribs.iter().any(|c| c.params.len() != global.len()) {
        return Err(Error::invalid("contribution length mismatch"));
    }
    if !(temp > 0.0) {
        return Err(Error::invalid("temperature must be positive"));
    }
    let mut out = vec![0.0f32; global.len()];
    for l in layers {
        let seg = l.offset..l.offset + l.size;
        // per-client L2 distance to the global layer
        let dists: Vec<f64> = contribs
            .iter()
            .map(|c| {
                c.params[seg.clone()]
                    .iter()
                    .zip(&global[seg.clone()])
                    .map(|(a, b)| ((a - b) as f64).powi(2))
                    .sum::<f64>()
                    .sqrt()
            })
            .collect();
        let mean_d = dists.iter().sum::<f64>() / dists.len() as f64;
        let scale = if mean_d > 0.0 { temp * mean_d } else { 1.0 };
        let logits: Vec<f64> = dists.iter().map(|d| -d / scale).collect();
        let max_logit = logits.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        let exps: Vec<f64> = logits.iter().map(|z| (z - max_logit).exp()).collect();
        let z: f64 = exps.iter().sum();
        for (c, w) in contribs.iter().zip(exps.iter().map(|e| e / z)) {
            for (slot, &v) in out[seg.clone()].iter_mut().zip(&c.params[seg.clone()]) {
                *slot += (w * v as f64) as f32;
            }
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::check;

    fn one_layer(size: usize) -> Vec<crate::runtime::manifest::LayerInfo> {
        vec![crate::runtime::manifest::LayerInfo {
            name: "w".into(),
            shape: vec![size],
            offset: 0,
            size,
            masked: true,
        }]
    }

    #[test]
    fn attentive_equal_contribs_is_identity() {
        let global = vec![0.0f32; 8];
        let a = vec![1.0f32; 8];
        let contribs = vec![
            Contribution { params: &a, n_samples: 1 },
            Contribution { params: &a, n_samples: 1 },
        ];
        let out = attentive_mean(&global, &contribs, &one_layer(8), 1.0).unwrap();
        for v in out {
            assert!((v - 1.0).abs() < 1e-6);
        }
    }

    #[test]
    fn attentive_downweights_outlier() {
        let global = vec![0.0f32; 16];
        let near: Vec<f32> = vec![0.1; 16];
        let far: Vec<f32> = vec![10.0; 16];
        let contribs = vec![
            Contribution { params: &near, n_samples: 1 },
            Contribution { params: &near, n_samples: 1 },
            Contribution { params: &far, n_samples: 1 },
        ];
        let attn = attentive_mean(&global, &contribs, &one_layer(16), 0.5).unwrap();
        let plain = uniform_mean(&contribs).unwrap();
        assert!(
            attn[0] < plain[0],
            "attentive {} should pull toward the near majority vs mean {}",
            attn[0],
            plain[0]
        );
    }

    #[test]
    fn attentive_rejects_bad_inputs() {
        let global = vec![0.0f32; 4];
        assert!(attentive_mean(&global, &[], &one_layer(4), 1.0).is_err());
        let a = vec![1.0f32; 4];
        let c = vec![Contribution { params: &a, n_samples: 1 }];
        assert!(attentive_mean(&global, &c, &one_layer(4), 0.0).is_err());
    }

    #[test]
    fn equal_weights_reduce_to_plain_mean() {
        let a = vec![1.0f32, 2.0, 3.0];
        let b = vec![3.0f32, 4.0, 5.0];
        let out = weighted_mean(&[
            Contribution { params: &a, n_samples: 10 },
            Contribution { params: &b, n_samples: 10 },
        ])
        .unwrap();
        assert_eq!(out, vec![2.0, 3.0, 4.0]);
    }

    #[test]
    fn weights_follow_sample_counts() {
        let a = vec![0.0f32];
        let b = vec![4.0f32];
        let out = weighted_mean(&[
            Contribution { params: &a, n_samples: 3 },
            Contribution { params: &b, n_samples: 1 },
        ])
        .unwrap();
        assert!((out[0] - 1.0).abs() < 1e-7);
    }

    #[test]
    fn rejects_degenerate_inputs() {
        assert!(weighted_mean(&[]).is_err());
        let a = vec![1.0f32, 2.0];
        let b = vec![1.0f32];
        assert!(weighted_mean(&[
            Contribution { params: &a, n_samples: 1 },
            Contribution { params: &b, n_samples: 1 },
        ])
        .is_err());
        assert!(weighted_mean(&[Contribution { params: &a, n_samples: 0 }]).is_err());
    }

    #[test]
    fn single_contribution_is_identity() {
        let a = vec![1.5f32, -2.5, 0.0];
        let out = weighted_mean(&[Contribution { params: &a, n_samples: 7 }]).unwrap();
        assert_eq!(out, a);
    }

    #[test]
    fn prop_mean_within_value_envelope() {
        check("aggregate envelope", 80, |g| {
            let p = g.usize_in(1, 300);
            let k = g.usize_in(1, 8);
            let vecs: Vec<Vec<f32>> = (0..k).map(|_| g.normal_vec(p)).collect();
            let contribs: Vec<Contribution> = vecs
                .iter()
                .map(|v| Contribution {
                    params: v,
                    n_samples: 1 + (g.seed % 100) as u32,
                })
                .collect();
            let out = weighted_mean(&contribs).unwrap();
            for j in 0..p {
                let lo = vecs.iter().map(|v| v[j]).fold(f32::INFINITY, f32::min);
                let hi = vecs.iter().map(|v| v[j]).fold(f32::NEG_INFINITY, f32::max);
                assert!(out[j] >= lo - 1e-5 && out[j] <= hi + 1e-5);
            }
        });
    }

    #[test]
    fn prop_uniform_equals_weighted_when_counts_equal() {
        check("uniform == weighted under equal counts", 50, |g| {
            let p = g.usize_in(1, 200);
            let k = g.usize_in(1, 6);
            let vecs: Vec<Vec<f32>> = (0..k).map(|_| g.normal_vec(p)).collect();
            let cs: Vec<Contribution> = vecs
                .iter()
                .map(|v| Contribution { params: v, n_samples: 42 })
                .collect();
            let a = weighted_mean(&cs).unwrap();
            let b = uniform_mean(&cs).unwrap();
            for (x, y) in a.iter().zip(&b) {
                assert!((x - y).abs() < 1e-6);
            }
        });
    }

    #[test]
    fn masked_zeros_dilute_the_mean() {
        // paper-literal semantics: a masked (zero) entry pulls the average
        // toward zero rather than being skipped
        let a = vec![2.0f32];
        let b = vec![0.0f32]; // masked out at this position
        let out = weighted_mean(&[
            Contribution { params: &a, n_samples: 1 },
            Contribution { params: &b, n_samples: 1 },
        ])
        .unwrap();
        assert_eq!(out[0], 1.0);
    }
}
