//! The federated server: Alg. 1 (static) / Alg. 3 (dynamic), end to end.
//!
//! Per round `t` (1-based): compute the sampling rate, run the ACK
//! selection loop against the availability model, broadcast the global
//! model (downlink accounting), fan client jobs out over the engine pool,
//! aggregate the returned (masked) models with weighted FedAvg, account
//! uplink cost, advance the virtual clock, and periodically evaluate on
//! the held-out test set.
//!
//! Determinism: client selection, shard shuffles and masking RNG all derive
//! from (seed, round, client); aggregation order is fixed by client id, so
//! the same config reproduces bit-identical runs regardless of pool width.

use std::sync::Arc;

use crate::config::experiment::{ExperimentConfig, NetworkKind};
use crate::data::{batcher, loader, partition, Dataset};
use crate::fl::aggregate::{weighted_mean, Contribution};
use crate::fl::client::{ClientJob, LocalOutcome, ShardRef};
use crate::metrics::recorder::{RoundRecord, RunRecorder};
use crate::runtime::engine::EvalSums;
use crate::runtime::manifest::Manifest;
use crate::runtime::pool::EnginePool;
use crate::runtime::tensor::Batches;
use crate::sim::availability::{AvailabilityModel, ClientState};
use crate::sim::clock::VirtualClock;
use crate::sim::rng::Rng;
use crate::transport::codec::wire_bytes;
use crate::transport::cost::CostLedger;
use crate::transport::network::NetworkModel;
use crate::util::error::{Error, Result};

/// Result of a completed run.
#[derive(Debug)]
pub struct ServerOutcome {
    pub recorder: RunRecorder,
    pub final_params: Vec<f32>,
    pub ledger: CostLedger,
}

/// The coordinator.
pub struct Server {
    cfg: Arc<ExperimentConfig>,
    pool: Arc<EnginePool>,
    dataset: Arc<Dataset>,
    shards: Vec<ShardRef>,
    eval_chunks: Arc<Vec<Batches>>,
    params: Arc<Vec<f32>>,
    p: usize,
    layers: Vec<crate::runtime::manifest::LayerInfo>,
    ledger: CostLedger,
    clock: VirtualClock,
    availability: AvailabilityModel,
    network: NetworkModel,
    recorder: RunRecorder,
}

impl Server {
    /// Build a server: load + partition data, spin up the engine pool,
    /// initialize the global model through the init artifact.
    pub fn new(cfg: ExperimentConfig, manifest: &Manifest) -> Result<Server> {
        cfg.validate()?;
        let pool = Arc::new(EnginePool::new(manifest, &[cfg.model.as_str()], cfg.workers)?);
        Server::with_pool(cfg, manifest, pool)
    }

    /// Build a server over an existing pool (figure sweeps share one pool
    /// across many configs to amortize artifact compilation).
    pub fn with_pool(
        cfg: ExperimentConfig,
        manifest: &Manifest,
        pool: Arc<EnginePool>,
    ) -> Result<Server> {
        cfg.validate()?;
        let mm = manifest.model(&cfg.model)?.clone();
        let spec = cfg.dataset_spec()?;
        let dataset = Arc::new(loader::load(&spec, std::path::Path::new("data"))?);

        // Partition across M clients.
        let mut prng = Rng::new(cfg.seed).fork(0xda7a);
        let shards: Vec<ShardRef> = match &*dataset {
            Dataset::Image { train, .. } => {
                partition::partition_images(&train.y, cfg.clients, cfg.partition, &mut prng)?
                    .into_iter()
                    .map(ShardRef::Image)
                    .collect()
            }
            Dataset::Text { train, .. } => partition::partition_text(train.len(), cfg.clients)?
                .into_iter()
                .map(ShardRef::Text)
                .collect(),
        };

        // Pre-build eval chunks once.
        let eval_chunks = Arc::new(match &*dataset {
            Dataset::Image { test, .. } => {
                batcher::image_eval_chunks(test, &mm, cfg.eval_max_chunks)?
            }
            Dataset::Text { test, .. } => {
                batcher::text_eval_chunks(test, &mm, cfg.eval_max_chunks)?
            }
        });

        // Global model init through the artifact (seeded).
        let model = cfg.model.clone();
        let seed = cfg.seed as i32;
        let params = pool
            .submit(move |e| e.init(&model, seed))
            .recv()
            .map_err(|_| Error::Engine("init job lost".into()))??;
        let p = params.len();

        let availability = AvailabilityModel::new(cfg.ack_prob, cfg.straggler_prob, cfg.seed ^ 0xacc);
        let network = match cfg.network {
            NetworkKind::Ideal => NetworkModel::ideal(),
            NetworkKind::Simulated => NetworkModel::default(),
        };
        let recorder = RunRecorder::new(cfg.label.clone());

        Ok(Server {
            cfg: Arc::new(cfg),
            pool,
            dataset,
            shards,
            eval_chunks,
            params: Arc::new(params),
            p,
            layers: mm.layers.clone(),
            ledger: CostLedger::new(),
            clock: VirtualClock::new(),
            availability,
            network,
            recorder,
        })
    }

    pub fn config(&self) -> &ExperimentConfig {
        &self.cfg
    }

    pub fn params(&self) -> &[f32] {
        &self.params
    }

    /// ACK selection loop (Alg. 1/3 lines 9–14): walk a seeded permutation
    /// of the registry, requesting connections until `want` clients ACK.
    /// Returns `(completers, stragglers)` — stragglers ACKed (and therefore
    /// receive the broadcast, paying downlink) but miss the round deadline
    /// and are dropped before aggregation. Both lists sorted for
    /// deterministic aggregation order.
    fn select_clients(&self, round: usize, want: usize) -> (Vec<usize>, Vec<usize>) {
        let mut order: Vec<usize> = (0..self.cfg.clients).collect();
        let mut rng = Rng::new(self.cfg.seed).fork(round as u64).fork(0x5e1);
        rng.shuffle(&mut order);
        let mut completers = Vec::with_capacity(want);
        let mut stragglers = Vec::new();
        for &c in &order {
            if completers.len() + stragglers.len() >= want {
                break;
            }
            match self.availability.state(round as u64, c as u64) {
                ClientState::Available => completers.push(c),
                ClientState::Straggler => stragglers.push(c),
                ClientState::Offline => {}
            }
        }
        if completers.is_empty() {
            // Degenerate availability: fall back to the first candidate so a
            // run cannot deadlock (logged; the paper assumes full ACK).
            log::warn!("round {round}: no client completed; forcing client {}", order[0]);
            completers.push(order[0]);
            stragglers.retain(|&c| c != order[0]);
        }
        completers.sort_unstable();
        stragglers.sort_unstable();
        (completers, stragglers)
    }

    /// Execute one round (1-based `t`). Returns the round record.
    pub fn run_round(&mut self, t: usize) -> Result<RoundRecord> {
        let rate = self.cfg.sampling.rate(t);
        let want = self
            .cfg
            .sampling
            .num_clients(t, self.cfg.clients, self.cfg.min_clients);
        let (selected, stragglers) = self.select_clients(t, want);

        // Downlink: broadcast the dense global model to every client that
        // ACKed — stragglers included (their download is spent bandwidth
        // even though their update misses the deadline).
        let download_bytes = wire_bytes(self.p, self.p, crate::transport::codec::Encoding::Dense);
        for _ in selected.iter().chain(&stragglers) {
            self.ledger.record_download(download_bytes);
        }
        if !stragglers.is_empty() {
            log::debug!("round {t}: {} stragglers dropped past deadline", stragglers.len());
        }

        // Fan out local training.
        let jobs: Vec<_> = selected
            .iter()
            .map(|&cid| {
                let job = ClientJob {
                    client_id: cid,
                    round: t,
                    dataset: Arc::clone(&self.dataset),
                    shard: self.shards[cid].clone(),
                    global: Arc::clone(&self.params),
                    cfg: Arc::clone(&self.cfg),
                };
                move |e: &crate::runtime::engine::Engine| job.run(e)
            })
            .collect();
        let outcomes: Vec<LocalOutcome> = self
            .pool
            .map(jobs)?
            .into_iter()
            .collect::<Result<Vec<_>>>()?;

        // Aggregate: sample-weighted FedAvg (Eq. 2) or attentive (Ji [11]).
        let contribs: Vec<Contribution> = outcomes
            .iter()
            .map(|o| Contribution {
                params: &o.params,
                n_samples: o.n_samples,
            })
            .collect();
        self.params = Arc::new(match self.cfg.aggregator {
            crate::config::experiment::Aggregator::FedAvg => weighted_mean(&contribs)?,
            crate::config::experiment::Aggregator::Attentive { temp } => {
                let layers = &self.layers;
                crate::fl::aggregate::attentive_mean(&self.params, &contribs, layers, temp)?
            }
        });

        // Uplink accounting + virtual time.
        let mut upload_sizes = Vec::with_capacity(outcomes.len());
        for o in &outcomes {
            self.ledger.record_upload(self.p, o.nnz, o.upload_bytes);
            upload_sizes.push(o.upload_bytes);
        }
        let compute_s = selected
            .iter()
            .map(|&c| {
                self.availability
                    .compute_time(t as u64, c as u64, self.cfg.local_epochs)
            })
            .fold(0.0f64, f64::max);
        self.clock.advance(self.network.download_time(download_bytes));
        self.clock.advance(compute_s);
        self.clock
            .advance(self.network.upload_round_time(&upload_sizes));

        let train_loss = outcomes.iter().map(|o| o.train_loss as f64).sum::<f64>()
            / outcomes.len() as f64;

        // Periodic evaluation.
        let eval = if t % self.cfg.eval_every == 0 || t == self.cfg.rounds {
            Some(self.evaluate()?)
        } else {
            None
        };

        let rec = RoundRecord {
            round: t,
            sample_rate: rate,
            clients: selected.len(),
            train_loss,
            test_loss: eval.map(|e| e.mean_loss()).unwrap_or(f64::NAN),
            test_accuracy: eval.map(|e| e.accuracy()).unwrap_or(f64::NAN),
            test_perplexity: eval.map(|e| e.perplexity()).unwrap_or(f64::NAN),
            uplink_units: self.ledger.uplink_units,
            uplink_bytes: self.ledger.uplink_bytes,
            virtual_time_s: self.clock.now(),
        };
        self.recorder.push(rec.clone());
        Ok(rec)
    }

    /// Evaluate the current global model over the pre-built eval chunks,
    /// fanned out across the pool.
    pub fn evaluate(&self) -> Result<EvalSums> {
        let jobs: Vec<_> = (0..self.eval_chunks.len())
            .map(|i| {
                let chunks = Arc::clone(&self.eval_chunks);
                let params = Arc::clone(&self.params);
                let model = self.cfg.model.clone();
                move |e: &crate::runtime::engine::Engine| e.eval_chunk(&model, &params, &chunks[i])
            })
            .collect();
        let mut total = EvalSums::default();
        for s in self.pool.map(jobs)? {
            total.add(s?);
        }
        Ok(total)
    }

    /// Run all configured rounds.
    pub fn run(mut self) -> Result<ServerOutcome> {
        let rounds = self.cfg.rounds;
        for t in 1..=rounds {
            let rec = self.run_round(t)?;
            log::info!(
                "[{}] round {t}/{rounds}: clients={} rate={:.3} loss={:.4} acc={:.4} cost={:.2}u",
                self.cfg.label,
                rec.clients,
                rec.sample_rate,
                rec.train_loss,
                rec.test_accuracy,
                rec.uplink_units,
            );
        }
        Ok(ServerOutcome {
            recorder: self.recorder,
            final_params: Arc::try_unwrap(self.params).unwrap_or_else(|arc| (*arc).clone()),
            ledger: self.ledger,
        })
    }
}
