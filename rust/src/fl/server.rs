//! The federated server: Alg. 1 (static) / Alg. 3 (dynamic), end to end.
//!
//! Since the full-duplex session refactor the server is deliberately
//! thin: the communication plane — transport construction, per-client
//! session registration, the four-phase round cycle (sample → broadcast →
//! collect → finalize), downlink reference state, and the cost ledger —
//! lives in [`RoundDriver`](crate::fl::driver::RoundDriver), which is
//! engine-free and unit-tested on its own. What remains here is the
//! *simulation* half: data loading and partitioning, the engine pool,
//! fanning [`ClientJob`]s out between the broadcast and collect phases,
//! periodic evaluation, the virtual clock, and the round record.
//!
//! Per round `t` (1-based): the driver samples the cohort against the
//! availability model, encodes and **pushes the broadcast through the
//! transport's downlink half** (in-process mailboxes by default, the
//! persistent authenticated TCP/UDS sessions under `--transport
//! tcp|uds`), the server fans client jobs out over the engine pool (each
//! job *receives its broadcast from the wire*, trains, masks, encodes,
//! and uploads through the same session), and the driver's collect phase
//! streams the uploads into the configured
//! [`Aggregator`](crate::fl::aggregate::Aggregator) in completion order —
//! a select-style wait that surfaces a dead client's concrete job error
//! within one poll tick. Sparse payloads fold in O(nnz); the server's
//! per-round cost is O(sum_i nnz_i + p).
//!
//! Determinism: client selection, shard shuffles and masking RNG all derive
//! from (seed, round, client); the broadcast bytes are a pure function of
//! the global model and config; the streaming FedAvg fold is
//! order-independent by construction (integer fixed-point accumulation)
//! and the attentive fold canonicalizes by client id at finish, so the
//! same config reproduces bit-identical runs regardless of pool width,
//! arrival order, or transport.

use std::sync::Arc;

use crate::config::experiment::{ExperimentConfig, NetworkKind};
use crate::data::{batcher, loader, partition, Dataset};
use crate::fl::aggregate::make_aggregator;
use crate::fl::client::{ClientJob, ShardRef};
use crate::fl::driver::RoundDriver;
use crate::metrics::recorder::{RoundRecord, RunRecorder};
use crate::runtime::engine::EvalSums;
use crate::runtime::manifest::Manifest;
use crate::runtime::pool::EnginePool;
use crate::runtime::tensor::Batches;
use crate::sim::availability::AvailabilityModel;
use crate::sim::clock::VirtualClock;
use crate::sim::rng::Rng;
use crate::transport::cost::CostLedger;
use crate::transport::network::NetworkModel;
use crate::util::error::{Error, Result};

/// Result of a completed run.
#[derive(Debug)]
pub struct ServerOutcome {
    pub recorder: RunRecorder,
    pub final_params: Vec<f32>,
    pub ledger: CostLedger,
}

/// The coordinator.
pub struct Server {
    cfg: Arc<ExperimentConfig>,
    pool: Arc<EnginePool>,
    dataset: Arc<Dataset>,
    shards: Vec<ShardRef>,
    eval_chunks: Arc<Vec<Batches>>,
    params: Arc<Vec<f32>>,
    p: usize,
    layers: Vec<crate::runtime::manifest::LayerInfo>,
    /// The communication plane: transport + sessions + downlink state +
    /// ledger, cycled through its four phases every round.
    driver: RoundDriver,
    clock: VirtualClock,
    availability: AvailabilityModel,
    network: NetworkModel,
    recorder: RunRecorder,
}

impl Server {
    /// Build a server: load + partition data, spin up the engine pool,
    /// initialize the global model through the init artifact.
    pub fn new(cfg: ExperimentConfig, manifest: &Manifest) -> Result<Server> {
        cfg.validate()?;
        let pool = Arc::new(EnginePool::new(manifest, &[cfg.model.as_str()], cfg.workers)?);
        Server::with_pool(cfg, manifest, pool)
    }

    /// Build a server over an existing pool (figure sweeps share one pool
    /// across many configs to amortize artifact compilation).
    pub fn with_pool(
        cfg: ExperimentConfig,
        manifest: &Manifest,
        pool: Arc<EnginePool>,
    ) -> Result<Server> {
        cfg.validate()?;
        let mm = manifest.model(&cfg.model)?.clone();
        let spec = cfg.dataset_spec()?;
        let dataset = Arc::new(loader::load(&spec, std::path::Path::new("data"))?);

        // Partition across M clients.
        let mut prng = Rng::new(cfg.seed).fork(0xda7a);
        let shards: Vec<ShardRef> = match &*dataset {
            Dataset::Image { train, .. } => {
                partition::partition_images(&train.y, cfg.clients, cfg.partition, &mut prng)?
                    .into_iter()
                    .map(ShardRef::Image)
                    .collect()
            }
            Dataset::Text { train, .. } => partition::partition_text(train.len(), cfg.clients)?
                .into_iter()
                .map(ShardRef::Text)
                .collect(),
        };

        // Pre-build eval chunks once.
        let eval_chunks = Arc::new(match &*dataset {
            Dataset::Image { test, .. } => {
                batcher::image_eval_chunks(test, &mm, cfg.eval_max_chunks)?
            }
            Dataset::Text { test, .. } => {
                batcher::text_eval_chunks(test, &mm, cfg.eval_max_chunks)?
            }
        });

        // Global model init through the artifact (seeded).
        let model = cfg.model.clone();
        let seed = cfg.seed as i32;
        let params = pool
            .submit(move |e| e.init(&model, seed))
            .recv()
            .map_err(|_| Error::Engine("init job lost".into()))??;
        let p = params.len();

        let availability = cfg.availability();
        let network = match cfg.network {
            NetworkKind::Ideal => NetworkModel::ideal(),
            NetworkKind::Simulated => NetworkModel::default(),
        };
        let recorder = RunRecorder::new(cfg.label.clone());
        let cfg = Arc::new(cfg);
        // The communication plane: builds the configured transport.
        // Sessions open lazily, per cohort, at each round's broadcast.
        let mut driver = RoundDriver::new(Arc::clone(&cfg), p)?;
        // Close the payload-recycling loop: serially folded frames return
        // to the pool the workers encode out of, so steady-state rounds
        // perform zero encode-side heap allocation (tests/alloc_count.rs).
        driver.attach_buffer_pool(Arc::clone(pool.buffer_pool()));

        Ok(Server {
            cfg,
            pool,
            dataset,
            shards,
            eval_chunks,
            params: Arc::new(params),
            p,
            layers: mm.layers.clone(),
            driver,
            clock: VirtualClock::new(),
            availability,
            network,
            recorder,
        })
    }

    pub fn config(&self) -> &ExperimentConfig {
        &self.cfg
    }

    pub fn params(&self) -> &[f32] {
        &self.params
    }

    /// Execute one round (1-based `t`). Returns the round record.
    pub fn run_round(&mut self, t: usize) -> Result<RoundRecord> {
        // Phase 1 — sample the cohort from the registered fleet.
        let cohort = self.driver.sample(&self.availability, t);

        // Phase 2 — encode the downlink and push it through the wire to
        // every completer (stragglers are billed, not wired).
        let wire = self.driver.broadcast(&self.params, &cohort)?;

        // Fan out local training. Jobs are scratch-aware: each worker's
        // long-lived buffers back the masking + encode temporaries. Each
        // job *receives the round's broadcast from the transport's
        // downlink half* (decoding / delta-reconstructing it itself —
        // bitwise the driver's canonical state), and its encoded payload
        // leaves through the round's upload sink the moment it exists;
        // only sideband metadata (loss, nnz, byte count) returns through
        // the pool channel.
        let sink = self.driver.sink();
        let downlink = self.driver.downlink();
        // `wire.spawn` filters out clients whose downlink the fault plan
        // disconnected mid-broadcast: they never received w_t, so they
        // have no round to run. All-true without the chaos harness.
        let jobs: Vec<_> = cohort
            .selected
            .iter()
            .enumerate()
            .filter(|&(i, _)| wire.spawn[i])
            .map(|(i, &cid)| {
                let job = ClientJob {
                    client_id: cid,
                    round: t,
                    dataset: Arc::clone(&self.dataset),
                    shard: self.shards[cid].clone(),
                    downlink: Arc::clone(&downlink),
                    reference: wire.references[i].clone(),
                    index_cache: wire.index_caches[i].clone(),
                    cfg: Arc::clone(&self.cfg),
                };
                let sink = Arc::clone(&sink);
                move |e: &crate::runtime::engine::Engine,
                      s: &mut crate::runtime::pool::WorkerScratch|
                      -> Result<(f32, usize, usize)> {
                    let outcome = job.run(e, s)?;
                    let bytes = outcome.payload.len();
                    sink.send(outcome.payload)?;
                    Ok((outcome.train_loss, outcome.nnz, bytes))
                }
            })
            .collect();

        // Phase 3 — collect: stream the uploads into the aggregator in
        // completion order while surfacing any job's concrete error
        // within a poll tick. With `agg_shards > 1` the fold itself runs
        // on shard worker threads (tree aggregation) and the partials
        // merge bitwise-exactly at finish — same result, parallel decode.
        let n_jobs = jobs.len();
        let results = self.pool.map_unordered_with(jobs);
        let (collected, finished) = if self.cfg.agg_shards > 1 {
            let partials = (0..self.cfg.agg_shards)
                .map(|_| {
                    make_aggregator(
                        self.cfg.aggregator,
                        self.cfg.mask_target,
                        &wire.params,
                        &self.layers,
                    )
                })
                .collect::<Result<Vec<_>>>()?;
            let mut tree = crate::fl::tree::ShardedAggregator::spawn(partials)?;
            let collected = self.driver.collect_sharded(&cohort, &mut tree, &results)?;
            (collected, tree.finish()?)
        } else {
            let mut agg = make_aggregator(
                self.cfg.aggregator,
                self.cfg.mask_target,
                &wire.params,
                &self.layers,
            )?;
            let collected = self.driver.collect(&cohort, agg.as_mut(), &results)?;
            (collected, agg.finish()?)
        };
        self.params = Arc::new(finished);

        // Phase 4 — finalize: uplink accounting in client-id order.
        let cost = self.driver.finalize(&collected);

        // Virtual time: slowest download, slowest compute, the round's
        // uploads.
        let compute_s = cohort
            .selected
            .iter()
            .enumerate()
            .filter(|&(i, _)| wire.spawn[i])
            .map(|(_, &c)| {
                self.availability
                    .compute_time(t as u64, c as u64, self.cfg.local_epochs)
            })
            .fold(0.0f64, f64::max);
        self.clock.advance(self.network.download_time(wire.slowest_download));
        self.clock.advance(compute_s);
        self.clock
            .advance(self.network.upload_round_time(&cost.upload_sizes));

        let train_loss = cost.loss_sum / n_jobs as f64;

        // Periodic evaluation.
        let eval = if t % self.cfg.eval_every == 0 || t == self.cfg.rounds {
            Some(self.evaluate()?)
        } else {
            None
        };

        let ledger = self.driver.ledger();
        let rec = RoundRecord {
            round: t,
            sample_rate: cohort.rate,
            clients: cohort.selected.len(),
            train_loss,
            test_loss: eval.map(|e| e.mean_loss()).unwrap_or(f64::NAN),
            test_accuracy: eval.map(|e| e.accuracy()).unwrap_or(f64::NAN),
            test_perplexity: eval.map(|e| e.perplexity()).unwrap_or(f64::NAN),
            uplink_units: ledger.uplink_units,
            uplink_bytes: ledger.uplink_bytes,
            downlink_bytes: ledger.downlink_bytes,
            downlink_recon_err: wire.recon_err,
            virtual_time_s: self.clock.now(),
            faults: self.driver.take_fault_log(t),
        };
        self.recorder.push(rec.clone());
        Ok(rec)
    }

    /// Evaluate the current global model over the pre-built eval chunks,
    /// fanned out across the pool.
    pub fn evaluate(&self) -> Result<EvalSums> {
        let jobs: Vec<_> = (0..self.eval_chunks.len())
            .map(|i| {
                let chunks = Arc::clone(&self.eval_chunks);
                let params = Arc::clone(&self.params);
                let model = self.cfg.model.clone();
                move |e: &crate::runtime::engine::Engine| e.eval_chunk(&model, &params, &chunks[i])
            })
            .collect();
        let mut total = EvalSums::default();
        for s in self.pool.map(jobs)? {
            total.add(s?);
        }
        Ok(total)
    }

    /// Run all configured rounds.
    pub fn run(mut self) -> Result<ServerOutcome> {
        let rounds = self.cfg.rounds;
        for t in 1..=rounds {
            let rec = self.run_round(t)?;
            log::info!(
                "[{}] round {t}/{rounds}: clients={} rate={:.3} loss={:.4} acc={:.4} cost={:.2}u",
                self.cfg.label,
                rec.clients,
                rec.sample_rate,
                rec.train_loss,
                rec.test_accuracy,
                rec.uplink_units,
            );
        }
        Ok(ServerOutcome {
            recorder: self.recorder,
            final_params: Arc::try_unwrap(self.params).unwrap_or_else(|arc| (*arc).clone()),
            ledger: self.driver.ledger().clone(),
        })
    }
}
