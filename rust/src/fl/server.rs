//! The federated server: Alg. 1 (static) / Alg. 3 (dynamic), end to end.
//!
//! Per round `t` (1-based): compute the sampling rate, run the ACK
//! selection loop against the availability model, broadcast the global
//! model (dense, or delta-encoded through the codec when
//! `downlink_delta` is set), fan client jobs out over the engine pool,
//! then **stream** aggregation: each client's encoded `WireUpdate` payload
//! travels through the configured
//! [`Transport`](crate::transport::link::Transport) — in-process channels
//! by default, framed TCP/UDS sockets under `--transport tcp|uds` — and is
//! decoded into a borrowed sparse/dense view (one [`DecodeScratch`]
//! held across rounds — no decode allocation at steady state) and folded
//! into the configured
//! [`Aggregator`](crate::fl::aggregate::Aggregator) the moment it lands,
//! in completion order — aggregation overlaps with the slowest clients'
//! compute instead of barriering on the cohort (except under
//! `network = "simulated"`, whose delivery-order modeling inherently
//! buffers the round's uploads before the first fold — see
//! [`Simulated`](crate::transport::link::Simulated)). Wire updates are matched
//! to the cohort by their own header (selected client, current round,
//! model dimension, no duplicates), so out-of-order socket delivery is
//! fine. Sparse payloads fold in
//! O(nnz); mask-target reconstruction is the aggregator's job now (the
//! delta baseline folds once at finish), so the server's per-round cost is
//! O(sum_i nnz_i + p) — the only O(p) passes are aggregator construction
//! and producing the finished global model. Uplink cost, virtual time
//! and the round record are accounted afterwards in client-id order.
//!
//! Determinism: client selection, shard shuffles and masking RNG all derive
//! from (seed, round, client); the streaming FedAvg fold is
//! order-independent by construction (integer fixed-point accumulation)
//! and the attentive fold canonicalizes by client id at finish, so the
//! same config reproduces bit-identical runs regardless of pool width or
//! arrival order.

use std::sync::Arc;
use std::time::Duration;

use crate::config::experiment::{ExperimentConfig, NetworkKind};
use crate::data::{batcher, loader, partition, Dataset};
use crate::fl::aggregate::{make_aggregator, Contribution, SparseContribution};
use crate::fl::client::{ClientJob, ShardRef};
use crate::metrics::recorder::{RoundRecord, RunRecorder};
use crate::runtime::engine::EvalSums;
use crate::runtime::manifest::Manifest;
use crate::runtime::pool::EnginePool;
use crate::runtime::tensor::Batches;
use crate::sim::availability::{AvailabilityModel, ClientState};
use crate::sim::clock::VirtualClock;
use crate::sim::rng::Rng;
use crate::transport::codec::{
    decode_update, decode_update_view, encode_update, wire_bytes, BodyView, DecodeScratch, Encoding,
};
use crate::transport::cost::CostLedger;
use crate::transport::link::{InProcess, Simulated, Transport, TransportKind, UploadSink};
use crate::transport::network::NetworkModel;
use crate::transport::socket::Loopback;
use crate::util::error::{Error, Result};

/// Sentinel "client" id in downlink broadcast headers.
const BROADCAST_SENDER: u32 = u32::MAX;

/// Per-round budget of dropped invalid uploads. Under a socket transport
/// the listener is an open local port, so a stray peer can deliver a
/// well-framed message whose *payload* fails decode or cohort validation;
/// those cost the round nothing (mirroring the framing layer's
/// per-connection drops) — but a garbage firehose must not stall the
/// aggregation loop forever.
const MAX_REJECTED_UPLOADS: usize = 64;

/// Account one rejected (well-framed but invalid) upload, erroring once
/// the per-round budget is exhausted. On a closed wire (`tolerate` false —
/// in-process channels carry only our own cohort's payloads) an invalid
/// upload can only be an internal bug, so it fails the round precisely and
/// immediately instead of being dropped.
fn reject_upload(rejected: &mut usize, tolerate: bool, why: impl std::fmt::Display) -> Result<()> {
    if !tolerate {
        return Err(Error::invalid(format!("invalid upload: {why}")));
    }
    *rejected += 1;
    log::warn!("transport: dropping invalid upload ({why})");
    if *rejected > MAX_REJECTED_UPLOADS {
        return Err(Error::transport(format!(
            "dropped {rejected} invalid uploads this round; giving up"
        )));
    }
    Ok(())
}

/// Per-client downlink cost of one round's broadcast.
struct BroadcastWire {
    /// Encoded bytes for a client holding the previous broadcast state.
    delta_bytes: usize,
    /// Non-zeros in that message (unit-cost accounting).
    delta_nnz: usize,
    /// Encoded bytes for a client that needs the full model (first
    /// broadcast, or selected after sitting out the previous round).
    dense_bytes: usize,
}

/// Result of a completed run.
#[derive(Debug)]
pub struct ServerOutcome {
    pub recorder: RunRecorder,
    pub final_params: Vec<f32>,
    pub ledger: CostLedger,
}

/// The coordinator.
pub struct Server {
    cfg: Arc<ExperimentConfig>,
    pool: Arc<EnginePool>,
    dataset: Arc<Dataset>,
    shards: Vec<ShardRef>,
    eval_chunks: Arc<Vec<Batches>>,
    params: Arc<Vec<f32>>,
    /// The model clients received last round — the delta-downlink reference
    /// (None before the first broadcast or when `downlink_delta` is off).
    prev_broadcast: Option<Arc<Vec<f32>>>,
    /// Which clients received the **previous round's** broadcast (rebuilt
    /// every round — the delta is `w_t - w_{t-1}`, so a client that sat
    /// out round t-1 holds stale state, cannot apply it, and is billed a
    /// dense catch-up transfer instead).
    has_prev_broadcast: Vec<bool>,
    p: usize,
    layers: Vec<crate::runtime::manifest::LayerInfo>,
    ledger: CostLedger,
    clock: VirtualClock,
    availability: AvailabilityModel,
    network: NetworkModel,
    recorder: RunRecorder,
    /// Reusable decode buffers for the streaming aggregation loop — held
    /// across rounds so steady-state decoding never allocates.
    decode_scratch: DecodeScratch,
    /// The wire uploads travel: in-process channels, framed TCP/UDS
    /// sockets, or either wrapped in `NetworkModel`-timed delivery. Held
    /// for the server's lifetime (socket listeners bind once).
    transport: Box<dyn Transport>,
}

impl Server {
    /// Build a server: load + partition data, spin up the engine pool,
    /// initialize the global model through the init artifact.
    pub fn new(cfg: ExperimentConfig, manifest: &Manifest) -> Result<Server> {
        cfg.validate()?;
        let pool = Arc::new(EnginePool::new(manifest, &[cfg.model.as_str()], cfg.workers)?);
        Server::with_pool(cfg, manifest, pool)
    }

    /// Build a server over an existing pool (figure sweeps share one pool
    /// across many configs to amortize artifact compilation).
    pub fn with_pool(
        cfg: ExperimentConfig,
        manifest: &Manifest,
        pool: Arc<EnginePool>,
    ) -> Result<Server> {
        cfg.validate()?;
        let mm = manifest.model(&cfg.model)?.clone();
        let spec = cfg.dataset_spec()?;
        let dataset = Arc::new(loader::load(&spec, std::path::Path::new("data"))?);

        // Partition across M clients.
        let mut prng = Rng::new(cfg.seed).fork(0xda7a);
        let shards: Vec<ShardRef> = match &*dataset {
            Dataset::Image { train, .. } => {
                partition::partition_images(&train.y, cfg.clients, cfg.partition, &mut prng)?
                    .into_iter()
                    .map(ShardRef::Image)
                    .collect()
            }
            Dataset::Text { train, .. } => partition::partition_text(train.len(), cfg.clients)?
                .into_iter()
                .map(ShardRef::Text)
                .collect(),
        };

        // Pre-build eval chunks once.
        let eval_chunks = Arc::new(match &*dataset {
            Dataset::Image { test, .. } => {
                batcher::image_eval_chunks(test, &mm, cfg.eval_max_chunks)?
            }
            Dataset::Text { test, .. } => {
                batcher::text_eval_chunks(test, &mm, cfg.eval_max_chunks)?
            }
        });

        // Global model init through the artifact (seeded).
        let model = cfg.model.clone();
        let seed = cfg.seed as i32;
        let params = pool
            .submit(move |e| e.init(&model, seed))
            .recv()
            .map_err(|_| Error::Engine("init job lost".into()))??;
        let p = params.len();

        let availability = AvailabilityModel::new(cfg.ack_prob, cfg.straggler_prob, cfg.seed ^ 0xacc);
        let network = match cfg.network {
            NetworkKind::Ideal => NetworkModel::ideal(),
            NetworkKind::Simulated => NetworkModel::default(),
        };
        // Upload carrier: channels by default, real framed sockets on
        // request; a simulated network additionally re-orders deliveries
        // by virtual upload time. The aggregate is transport-invariant.
        let base: Box<dyn Transport> = match cfg.transport {
            TransportKind::InProcess => Box::new(InProcess::new()),
            TransportKind::Tcp | TransportKind::Uds => Box::new(Loopback::bind(cfg.transport)?),
        };
        let transport: Box<dyn Transport> = match cfg.network {
            NetworkKind::Ideal => base,
            NetworkKind::Simulated => Box::new(Simulated::new(base, network.clone())),
        };
        log::debug!("[{}] uploads travel via {}", cfg.label, transport.label());
        let recorder = RunRecorder::new(cfg.label.clone());
        let cfg_clients = cfg.clients;

        Ok(Server {
            cfg: Arc::new(cfg),
            pool,
            dataset,
            shards,
            eval_chunks,
            params: Arc::new(params),
            prev_broadcast: None,
            has_prev_broadcast: vec![false; cfg_clients],
            p,
            layers: mm.layers.clone(),
            ledger: CostLedger::new(),
            clock: VirtualClock::new(),
            availability,
            network,
            recorder,
            decode_scratch: DecodeScratch::default(),
            transport,
        })
    }

    pub fn config(&self) -> &ExperimentConfig {
        &self.cfg
    }

    pub fn params(&self) -> &[f32] {
        &self.params
    }

    /// ACK selection loop (Alg. 1/3 lines 9–14): walk a seeded permutation
    /// of the registry, requesting connections until `want` clients ACK.
    /// Returns `(completers, stragglers)` — stragglers ACKed (and therefore
    /// receive the broadcast, paying downlink) but miss the round deadline
    /// and are dropped before aggregation. Both lists sorted for
    /// deterministic aggregation order.
    fn select_clients(&self, round: usize, want: usize) -> (Vec<usize>, Vec<usize>) {
        let mut order: Vec<usize> = (0..self.cfg.clients).collect();
        let mut rng = Rng::new(self.cfg.seed).fork(round as u64).fork(0x5e1);
        rng.shuffle(&mut order);
        let mut completers = Vec::with_capacity(want);
        let mut stragglers = Vec::new();
        for &c in &order {
            if completers.len() + stragglers.len() >= want {
                break;
            }
            match self.availability.state(round as u64, c as u64) {
                ClientState::Available => completers.push(c),
                ClientState::Straggler => stragglers.push(c),
                ClientState::Offline => {}
            }
        }
        if completers.is_empty() {
            // Degenerate availability: fall back to the first candidate so a
            // run cannot deadlock (logged; the paper assumes full ACK).
            log::warn!("round {round}: no client completed; forcing client {}", order[0]);
            completers.push(order[0]);
            stragglers.retain(|&c| c != order[0]);
        }
        completers.sort_unstable();
        stragglers.sort_unstable();
        (completers, stragglers)
    }

    /// Encode this round's downlink broadcast through the codec. Returns
    /// the params clients receive plus the wire costs: delta bytes/nnz for
    /// a client that holds the previous broadcast state, dense bytes for
    /// one that must be caught up with the full model.
    ///
    /// Default: dense broadcast, clients share the global model verbatim.
    /// With `downlink_delta`: rounds after the first ship
    /// `w_t - w_{t-1}` through the configured encoding (sparse whenever a
    /// masked cohort left most coordinates untouched), and clients
    /// reconstruct `w_{t-1} + delta` — modeled here by decoding our own
    /// message, so lossy codecs affect the broadcast exactly as they would
    /// on a real wire. The delta stream is the canonical fleet-wide state:
    /// catch-up clients receive the same reconstructed params, just billed
    /// at dense cost.
    fn encode_broadcast(&mut self, t: usize) -> Result<(Arc<Vec<f32>>, BroadcastWire)> {
        let dense_bytes = wire_bytes(self.p, self.p, Encoding::Dense);
        if !self.cfg.downlink_delta {
            let wire = BroadcastWire {
                delta_bytes: dense_bytes,
                delta_nnz: self.p,
                dense_bytes,
            };
            return Ok((Arc::clone(&self.params), wire));
        }
        let (received, delta_bytes, delta_nnz) = match self.prev_broadcast.take() {
            None => {
                // First broadcast: no client-side reference model yet.
                let wire =
                    encode_update(BROADCAST_SENDER, t as u32, 0, &self.params, Encoding::Dense);
                (decode_update(&wire)?.into_dense(), wire.len(), self.p)
            }
            Some(prev) => {
                let delta: Vec<f32> = self
                    .params
                    .iter()
                    .zip(prev.iter())
                    .map(|(new, old)| new - old)
                    .collect();
                let nnz = delta.iter().filter(|v| **v != 0.0).count();
                let wire =
                    encode_update(BROADCAST_SENDER, t as u32, 0, &delta, self.cfg.encoding);
                let decoded = decode_update(&wire)?.into_dense();
                let received: Vec<f32> = decoded
                    .iter()
                    .zip(prev.iter())
                    .map(|(d, old)| old + d)
                    .collect();
                (received, wire.len(), nnz)
            }
        };
        let received = Arc::new(received);
        self.prev_broadcast = Some(Arc::clone(&received));
        Ok((
            received,
            BroadcastWire {
                delta_bytes,
                delta_nnz,
                dense_bytes,
            },
        ))
    }

    /// Execute one round (1-based `t`). Returns the round record.
    pub fn run_round(&mut self, t: usize) -> Result<RoundRecord> {
        let rate = self.cfg.sampling.rate(t);
        let want = self
            .cfg
            .sampling
            .num_clients(t, self.cfg.clients, self.cfg.min_clients);
        let (selected, stragglers) = self.select_clients(t, want);

        // Downlink: broadcast the global model to every client that ACKed —
        // stragglers included (their download is spent bandwidth even
        // though their update misses the deadline). Under delta encoding,
        // only clients that hold the previous broadcast state pay delta
        // bytes; the rest are caught up at dense cost.
        let (broadcast, wire) = self.encode_broadcast(t)?;
        let mut slowest_download = 0usize;
        let mut next_recipients = vec![false; self.cfg.clients];
        for &c in selected.iter().chain(&stragglers) {
            let (nnz, bytes) = if self.cfg.downlink_delta && self.has_prev_broadcast[c] {
                (wire.delta_nnz, wire.delta_bytes)
            } else {
                (self.p, wire.dense_bytes)
            };
            self.ledger.record_download_sparse(self.p, nnz, bytes);
            slowest_download = slowest_download.max(bytes);
            next_recipients[c] = true;
        }
        // Only this round's recipients hold w_t; everyone else goes stale
        // and pays dense next time they are sampled.
        self.has_prev_broadcast = next_recipients;
        if !stragglers.is_empty() {
            log::debug!("round {t}: {} stragglers dropped past deadline", stragglers.len());
        }

        // Fan out local training. Jobs are scratch-aware: each worker's
        // long-lived buffers back the masking + encode temporaries. The
        // encoded payload leaves through the round's transport sink the
        // moment it exists; only sideband metadata (loss, nnz, byte count)
        // returns through the pool channel.
        let sink = self.transport.sink();
        let jobs: Vec<_> = selected
            .iter()
            .map(|&cid| {
                let job = ClientJob {
                    client_id: cid,
                    round: t,
                    dataset: Arc::clone(&self.dataset),
                    shard: self.shards[cid].clone(),
                    global: Arc::clone(&broadcast),
                    cfg: Arc::clone(&self.cfg),
                };
                let sink = Arc::clone(&sink);
                move |e: &crate::runtime::engine::Engine,
                      s: &mut crate::runtime::pool::WorkerScratch|
                      -> Result<(f32, usize, usize)> {
                    let outcome = job.run(e, s)?;
                    let bytes = outcome.payload.len();
                    sink.send(outcome.payload)?;
                    Ok((outcome.train_loss, outcome.nnz, bytes))
                }
            })
            .collect();

        // Streaming aggregation: each completed job has already pushed its
        // payload into the transport, so for every metadata arrival we pull
        // one payload off the wire, decode it into a borrowed view (sparse
        // bodies stay sparse) and fold it — still overlapping the slowest
        // clients' compute. Payload and metadata arrival orders may differ
        // (sockets deliver in connection order, the simulated network in
        // upload-time order), so each wire update is matched to the cohort
        // by its own header: it must name a selected client, this round,
        // the right dimension, and no client may upload twice.
        // Metadata for cost/metric accounting is parked per input index so
        // the ledger and logs stay in deterministic client-id order.
        let n_jobs = jobs.len();
        self.transport.begin_round(n_jobs);
        let mut agg =
            make_aggregator(self.cfg.aggregator, self.cfg.mask_target, &broadcast, &self.layers)?;
        let mut metas: Vec<Option<(f32, usize, usize)>> = vec![None; n_jobs];
        let mut uploaded = vec![false; n_jobs];
        let mut rejected = 0usize;
        let tolerate_strays = self.transport.accepts_foreign_peers();
        let results = self.pool.map_unordered_with(jobs);
        for (idx, res) in &results {
            let meta = res?;
            // Pull payloads until one passes decode + cohort validation;
            // invalid ones are dropped on a bounded budget (fold failures
            // stay fatal — they can leave the accumulator partially
            // updated, and our own cohort's payloads are codec-clean).
            loop {
                let payload = match self.transport.recv() {
                    Ok(p) => p,
                    Err(te) => {
                        // A missing upload usually means a *later* job died
                        // before sending (under `Simulated` the first recv
                        // barriers on the whole cohort): drain the remaining
                        // job results and surface the concrete job error
                        // over the generic transport timeout when one
                        // exists.
                        while let Ok((_, r)) = results.recv_timeout(Duration::from_secs(5)) {
                            r?;
                        }
                        return Err(te);
                    }
                };
                let update = match decode_update_view(&payload, &mut self.decode_scratch) {
                    Ok(u) => u,
                    Err(e) => {
                        reject_upload(&mut rejected, tolerate_strays, e)?;
                        continue;
                    }
                };
                if update.round as usize != t {
                    reject_upload(
                        &mut rejected,
                        tolerate_strays,
                        format_args!(
                            "client {} names round {}, server is on round {t}",
                            update.client, update.round
                        ),
                    )?;
                    continue;
                }
                let pos = match selected.binary_search(&(update.client as usize)) {
                    Ok(pos) => pos,
                    Err(_) => {
                        reject_upload(
                            &mut rejected,
                            tolerate_strays,
                            format_args!("client {} not in this round's cohort", update.client),
                        )?;
                        continue;
                    }
                };
                if uploaded[pos] {
                    reject_upload(
                        &mut rejected,
                        tolerate_strays,
                        format_args!("duplicate update from client {}", update.client),
                    )?;
                    continue;
                }
                if update.p != self.p {
                    reject_upload(
                        &mut rejected,
                        tolerate_strays,
                        format_args!("carries {} params, model has {}", update.p, self.p),
                    )?;
                    continue;
                }
                uploaded[pos] = true;
                let client = update.client as usize;
                match update.body {
                    BodyView::Dense(params) => agg.fold(Contribution {
                        client,
                        params,
                        n_samples: update.n_samples,
                    })?,
                    BodyView::Sparse { indices, values } => agg.fold_sparse(SparseContribution {
                        client,
                        p: update.p,
                        indices,
                        values,
                        n_samples: update.n_samples,
                    })?,
                }
                break;
            }
            metas[idx] = Some(meta);
        }
        if agg.folded() < n_jobs {
            return Err(Error::Engine("worker dropped job (thread died?)".into()));
        }
        self.params = Arc::new(agg.finish()?);

        // Uplink accounting + virtual time, in client-id (input) order.
        let mut upload_sizes = Vec::with_capacity(n_jobs);
        let mut loss_sum = 0.0f64;
        for meta in &metas {
            let (train_loss, nnz, bytes) = meta.expect("all jobs accounted");
            self.ledger.record_upload(self.p, nnz, bytes);
            upload_sizes.push(bytes);
            loss_sum += train_loss as f64;
        }
        let compute_s = selected
            .iter()
            .map(|&c| {
                self.availability
                    .compute_time(t as u64, c as u64, self.cfg.local_epochs)
            })
            .fold(0.0f64, f64::max);
        self.clock.advance(self.network.download_time(slowest_download));
        self.clock.advance(compute_s);
        self.clock
            .advance(self.network.upload_round_time(&upload_sizes));

        let train_loss = loss_sum / n_jobs as f64;

        // Periodic evaluation.
        let eval = if t % self.cfg.eval_every == 0 || t == self.cfg.rounds {
            Some(self.evaluate()?)
        } else {
            None
        };

        let rec = RoundRecord {
            round: t,
            sample_rate: rate,
            clients: selected.len(),
            train_loss,
            test_loss: eval.map(|e| e.mean_loss()).unwrap_or(f64::NAN),
            test_accuracy: eval.map(|e| e.accuracy()).unwrap_or(f64::NAN),
            test_perplexity: eval.map(|e| e.perplexity()).unwrap_or(f64::NAN),
            uplink_units: self.ledger.uplink_units,
            uplink_bytes: self.ledger.uplink_bytes,
            downlink_bytes: self.ledger.downlink_bytes,
            virtual_time_s: self.clock.now(),
        };
        self.recorder.push(rec.clone());
        Ok(rec)
    }

    /// Evaluate the current global model over the pre-built eval chunks,
    /// fanned out across the pool.
    pub fn evaluate(&self) -> Result<EvalSums> {
        let jobs: Vec<_> = (0..self.eval_chunks.len())
            .map(|i| {
                let chunks = Arc::clone(&self.eval_chunks);
                let params = Arc::clone(&self.params);
                let model = self.cfg.model.clone();
                move |e: &crate::runtime::engine::Engine| e.eval_chunk(&model, &params, &chunks[i])
            })
            .collect();
        let mut total = EvalSums::default();
        for s in self.pool.map(jobs)? {
            total.add(s?);
        }
        Ok(total)
    }

    /// Run all configured rounds.
    pub fn run(mut self) -> Result<ServerOutcome> {
        let rounds = self.cfg.rounds;
        for t in 1..=rounds {
            let rec = self.run_round(t)?;
            log::info!(
                "[{}] round {t}/{rounds}: clients={} rate={:.3} loss={:.4} acc={:.4} cost={:.2}u",
                self.cfg.label,
                rec.clients,
                rec.sample_rate,
                rec.train_loss,
                rec.test_accuracy,
                rec.uplink_units,
            );
        }
        Ok(ServerOutcome {
            recorder: self.recorder,
            final_params: Arc::try_unwrap(self.params).unwrap_or_else(|arc| (*arc).clone()),
            ledger: self.ledger,
        })
    }
}
