//! The federated server: Alg. 1 (static) / Alg. 3 (dynamic), end to end.
//!
//! Per round `t` (1-based): compute the sampling rate, run the ACK
//! selection loop against the availability model, broadcast the global
//! model (dense, or delta-encoded through the codec when
//! `downlink_delta` is set), fan client jobs out over the engine pool,
//! then **stream** aggregation: each client's encoded `WireUpdate` payload
//! travels through the configured
//! [`Transport`](crate::transport::link::Transport) — in-process channels
//! by default, framed TCP/UDS sockets under `--transport tcp|uds` — and is
//! decoded into a borrowed sparse/dense view (one [`DecodeScratch`]
//! held across rounds — no decode allocation at steady state) and folded
//! into the configured
//! [`Aggregator`](crate::fl::aggregate::Aggregator) the moment it lands,
//! in completion order — aggregation overlaps with the slowest clients'
//! compute instead of barriering on the cohort (except under
//! `network = "simulated"`, whose delivery-order modeling inherently
//! buffers the round's uploads before the first fold — see
//! [`Simulated`](crate::transport::link::Simulated)). The drain is a
//! select-style wait over the pool-result channel and the wire
//! ([`drain_round_uploads`]): a client job that dies surfaces its concrete
//! error within one poll tick, never after the upload timeout. Wire updates are matched
//! to the cohort by their own header (selected client, current round,
//! model dimension, no duplicates), so out-of-order socket delivery is
//! fine. Sparse payloads fold in
//! O(nnz); mask-target reconstruction is the aggregator's job now (the
//! delta baseline folds once at finish), so the server's per-round cost is
//! O(sum_i nnz_i + p) — the only O(p) passes are aggregator construction
//! and producing the finished global model. Uplink cost, virtual time
//! and the round record are accounted afterwards in client-id order.
//!
//! Determinism: client selection, shard shuffles and masking RNG all derive
//! from (seed, round, client); the streaming FedAvg fold is
//! order-independent by construction (integer fixed-point accumulation)
//! and the attentive fold canonicalizes by client id at finish, so the
//! same config reproduces bit-identical runs regardless of pool width or
//! arrival order.

use std::sync::mpsc::{Receiver, RecvTimeoutError, TryRecvError};
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::config::experiment::{ExperimentConfig, NetworkKind};
use crate::data::{batcher, loader, partition, Dataset};
use crate::fl::aggregate::{make_aggregator, Aggregator, Contribution, SparseContribution};
use crate::fl::client::{ClientJob, ShardRef};
use crate::metrics::recorder::{RoundRecord, RunRecorder};
use crate::runtime::engine::EvalSums;
use crate::runtime::manifest::Manifest;
use crate::runtime::pool::EnginePool;
use crate::runtime::tensor::Batches;
use crate::sim::availability::{AvailabilityModel, ClientState};
use crate::sim::clock::VirtualClock;
use crate::sim::rng::Rng;
use crate::transport::codec::{
    decode_update, decode_update_view, encode_update, wire_bytes, BodyView, DecodeScratch, Encoding,
};
use crate::transport::cost::CostLedger;
use crate::transport::link::{
    InProcess, Simulated, Transport, TransportKind, UploadSink, DEFAULT_UPLOAD_TIMEOUT,
};
use crate::transport::network::NetworkModel;
use crate::transport::socket::Loopback;
use crate::util::error::{Error, Result};

/// Sentinel "client" id in downlink broadcast headers.
const BROADCAST_SENDER: u32 = u32::MAX;

/// Per-round budget of dropped invalid uploads. Under a socket transport
/// the listener is an open local port, so a stray peer can deliver a
/// well-framed message whose *payload* fails decode or cohort validation;
/// those cost the round nothing (mirroring the framing layer's
/// per-connection drops) — but a garbage firehose must not stall the
/// aggregation loop forever.
const MAX_REJECTED_UPLOADS: usize = 64;

/// Account one rejected (well-framed but invalid) upload, erroring once
/// the per-round budget is exhausted. On a closed wire (`tolerate` false —
/// in-process channels carry only our own cohort's payloads) an invalid
/// upload can only be an internal bug, so it fails the round precisely and
/// immediately instead of being dropped.
fn reject_upload(rejected: &mut usize, tolerate: bool, why: impl std::fmt::Display) -> Result<()> {
    if !tolerate {
        return Err(Error::invalid(format!("invalid upload: {why}")));
    }
    *rejected += 1;
    log::warn!("transport: dropping invalid upload ({why})");
    if *rejected > MAX_REJECTED_UPLOADS {
        return Err(Error::transport(format!(
            "dropped {rejected} invalid uploads this round; giving up"
        )));
    }
    Ok(())
}

/// Sideband metadata one client job reports through the pool channel:
/// (train loss, nnz, encoded payload bytes).
type JobMeta = (f32, usize, usize);

/// How long the drain loop waits on the wire before re-polling the pool's
/// result channel. Small enough that a dead client's concrete job error
/// surfaces within a poll tick; large enough that a healthy round spends
/// its time blocked in the transport, not spinning.
const DRAIN_POLL: Duration = Duration::from_millis(25);

/// Drain one round's uploads: a select-style wait over the **pool-result
/// channel** (job metadata / job errors) and the **wire** (encoded
/// payloads), folding each valid payload into `agg` the moment it lands.
///
/// The two streams are independent — a payload can beat its metadata and
/// vice versa — so the loop alternates: drain every ready pool result
/// (a failed client job surfaces its concrete error *here, immediately*,
/// instead of after the full upload timeout — the wire can never deliver
/// the payload a dead job didn't send), then wait at most [`DRAIN_POLL`]
/// for the next payload. Wire arrivals are matched to the cohort by their
/// own header (selected client, current round, model dimension, no
/// duplicates); invalid ones are dropped on a bounded budget when the
/// transport `tolerate_strays`, and fail the round precisely otherwise.
///
/// `upload_timeout` is an **inactivity** bound, matching the old per-recv
/// semantics: the window restarts whenever the round makes progress (a
/// payload folds or a job reports), so a large cohort legitimately
/// draining for longer than the timeout never trips it — only a round
/// where nothing happens for the whole window does.
///
/// Returns the per-job metadata in input (client-id) order once every job
/// reported and every upload folded. Free function by design: it needs no
/// engine, so the dead-client regression tests drive it directly with
/// hand-built channels and transports.
#[allow(clippy::too_many_arguments)] // round context; precedent: data/synth.rs
fn drain_round_uploads(
    transport: &mut dyn Transport,
    results: &Receiver<(usize, Result<JobMeta>)>,
    agg: &mut dyn Aggregator,
    scratch: &mut DecodeScratch,
    selected: &[usize],
    round: usize,
    p: usize,
    tolerate_strays: bool,
    upload_timeout: Duration,
) -> Result<Vec<JobMeta>> {
    let n_jobs = selected.len();
    let mut metas: Vec<Option<JobMeta>> = vec![None; n_jobs];
    let mut uploaded = vec![false; n_jobs];
    let mut metas_pending = n_jobs;
    let mut folds_pending = n_jobs;
    let mut rejected = 0usize;
    let mut results_open = true;
    // Inactivity deadline: pushed forward on every piece of progress.
    let mut deadline = Instant::now() + upload_timeout;

    while metas_pending > 0 || folds_pending > 0 {
        // 1) Surface every ready job result without blocking. `res?` is the
        //    headline path: a client job that died reports its concrete
        //    error here on the next poll tick.
        while results_open && metas_pending > 0 {
            match results.try_recv() {
                Ok((idx, res)) => {
                    metas[idx] = Some(res?);
                    metas_pending -= 1;
                    deadline = Instant::now() + upload_timeout;
                }
                Err(TryRecvError::Empty) => break,
                Err(TryRecvError::Disconnected) => results_open = false,
            }
        }
        if !results_open && metas_pending > 0 {
            // Every sender is gone but some job never reported: its worker
            // thread died (e.g. a panicking client) — fail now; the wire
            // will never deliver its upload.
            return Err(Error::Engine("worker dropped job (thread died?)".into()));
        }
        if folds_pending == 0 {
            // All payloads folded; only metadata is outstanding. Block on
            // the result channel directly (bounded by the round deadline).
            let window = deadline
                .checked_duration_since(Instant::now())
                .filter(|w| !w.is_zero())
                .ok_or_else(|| {
                    Error::transport(format!(
                        "timed out after {upload_timeout:?} waiting for job results"
                    ))
                })?;
            match results.recv_timeout(window.min(DRAIN_POLL)) {
                Ok((idx, res)) => {
                    metas[idx] = Some(res?);
                    metas_pending -= 1;
                    deadline = Instant::now() + upload_timeout;
                }
                Err(RecvTimeoutError::Timeout) => {}
                Err(RecvTimeoutError::Disconnected) => results_open = false,
            }
            continue;
        }

        // 2) Bounded wait for the next wire payload.
        let window = deadline
            .checked_duration_since(Instant::now())
            .filter(|w| !w.is_zero())
            .ok_or_else(|| {
                let missing: Vec<usize> = selected
                    .iter()
                    .zip(&uploaded)
                    .filter(|(_, up)| !**up)
                    .map(|(c, _)| *c)
                    .collect();
                Error::transport(format!(
                    "timed out after {upload_timeout:?} waiting for uploads from clients {missing:?}"
                ))
            })?;
        let Some(payload) = transport.try_recv_for(window.min(DRAIN_POLL))? else {
            continue;
        };

        // 3) Decode + cohort-validate + fold. Invalid payloads are dropped
        //    on a bounded budget (fold failures stay fatal — they can leave
        //    the accumulator partially updated, and our own cohort's
        //    payloads are codec-clean).
        let update = match decode_update_view(&payload, scratch) {
            Ok(u) => u,
            Err(e) => {
                reject_upload(&mut rejected, tolerate_strays, e)?;
                continue;
            }
        };
        if update.round as usize != round {
            reject_upload(
                &mut rejected,
                tolerate_strays,
                format_args!(
                    "client {} names round {}, server is on round {round}",
                    update.client, update.round
                ),
            )?;
            continue;
        }
        let pos = match selected.binary_search(&(update.client as usize)) {
            Ok(pos) => pos,
            Err(_) => {
                reject_upload(
                    &mut rejected,
                    tolerate_strays,
                    format_args!("client {} not in this round's cohort", update.client),
                )?;
                continue;
            }
        };
        if uploaded[pos] {
            reject_upload(
                &mut rejected,
                tolerate_strays,
                format_args!("duplicate update from client {}", update.client),
            )?;
            continue;
        }
        if update.p != p {
            reject_upload(
                &mut rejected,
                tolerate_strays,
                format_args!("carries {} params, model has {}", update.p, p),
            )?;
            continue;
        }
        uploaded[pos] = true;
        let client = update.client as usize;
        match update.body {
            BodyView::Dense(params) => agg.fold(Contribution {
                client,
                params,
                n_samples: update.n_samples,
            })?,
            BodyView::Sparse { indices, values } => agg.fold_sparse(SparseContribution {
                client,
                p: update.p,
                indices,
                values,
                n_samples: update.n_samples,
            })?,
        }
        folds_pending -= 1;
        deadline = Instant::now() + upload_timeout;
    }
    debug_assert_eq!(agg.folded(), n_jobs);
    Ok(metas.into_iter().map(|m| m.expect("all jobs accounted")).collect())
}

/// Per-client downlink cost of one round's broadcast.
struct BroadcastWire {
    /// Encoded bytes for a client holding the previous broadcast state.
    delta_bytes: usize,
    /// Non-zeros in that message (unit-cost accounting).
    delta_nnz: usize,
    /// Encoded bytes for a client that needs the full model (first
    /// broadcast, or selected after sitting out the previous round).
    dense_bytes: usize,
    /// Max |reconstructed - global| over all coordinates this round — the
    /// delta-downlink fidelity evidence (0.0 for dense broadcasts). The
    /// server asserts it against the codec's quantizer half-step; the
    /// figure sweeps record it per round so flipping the `downlink_delta`
    /// default is a data-backed decision.
    recon_err: f64,
}

/// Result of a completed run.
#[derive(Debug)]
pub struct ServerOutcome {
    pub recorder: RunRecorder,
    pub final_params: Vec<f32>,
    pub ledger: CostLedger,
}

/// The coordinator.
pub struct Server {
    cfg: Arc<ExperimentConfig>,
    pool: Arc<EnginePool>,
    dataset: Arc<Dataset>,
    shards: Vec<ShardRef>,
    eval_chunks: Arc<Vec<Batches>>,
    params: Arc<Vec<f32>>,
    /// The model clients received last round — the delta-downlink reference
    /// (None before the first broadcast or when `downlink_delta` is off).
    prev_broadcast: Option<Arc<Vec<f32>>>,
    /// Which clients received the **previous round's** broadcast (rebuilt
    /// every round — the delta is `w_t - w_{t-1}`, so a client that sat
    /// out round t-1 holds stale state, cannot apply it, and is billed a
    /// dense catch-up transfer instead).
    has_prev_broadcast: Vec<bool>,
    p: usize,
    layers: Vec<crate::runtime::manifest::LayerInfo>,
    ledger: CostLedger,
    clock: VirtualClock,
    availability: AvailabilityModel,
    network: NetworkModel,
    recorder: RunRecorder,
    /// Reusable decode buffers for the streaming aggregation loop — held
    /// across rounds so steady-state decoding never allocates.
    decode_scratch: DecodeScratch,
    /// The wire uploads travel: in-process channels, framed TCP/UDS
    /// sockets, or either wrapped in `NetworkModel`-timed delivery. Held
    /// for the server's lifetime (socket listeners bind once).
    transport: Box<dyn Transport>,
}

impl Server {
    /// Build a server: load + partition data, spin up the engine pool,
    /// initialize the global model through the init artifact.
    pub fn new(cfg: ExperimentConfig, manifest: &Manifest) -> Result<Server> {
        cfg.validate()?;
        let pool = Arc::new(EnginePool::new(manifest, &[cfg.model.as_str()], cfg.workers)?);
        Server::with_pool(cfg, manifest, pool)
    }

    /// Build a server over an existing pool (figure sweeps share one pool
    /// across many configs to amortize artifact compilation).
    pub fn with_pool(
        cfg: ExperimentConfig,
        manifest: &Manifest,
        pool: Arc<EnginePool>,
    ) -> Result<Server> {
        cfg.validate()?;
        let mm = manifest.model(&cfg.model)?.clone();
        let spec = cfg.dataset_spec()?;
        let dataset = Arc::new(loader::load(&spec, std::path::Path::new("data"))?);

        // Partition across M clients.
        let mut prng = Rng::new(cfg.seed).fork(0xda7a);
        let shards: Vec<ShardRef> = match &*dataset {
            Dataset::Image { train, .. } => {
                partition::partition_images(&train.y, cfg.clients, cfg.partition, &mut prng)?
                    .into_iter()
                    .map(ShardRef::Image)
                    .collect()
            }
            Dataset::Text { train, .. } => partition::partition_text(train.len(), cfg.clients)?
                .into_iter()
                .map(ShardRef::Text)
                .collect(),
        };

        // Pre-build eval chunks once.
        let eval_chunks = Arc::new(match &*dataset {
            Dataset::Image { test, .. } => {
                batcher::image_eval_chunks(test, &mm, cfg.eval_max_chunks)?
            }
            Dataset::Text { test, .. } => {
                batcher::text_eval_chunks(test, &mm, cfg.eval_max_chunks)?
            }
        });

        // Global model init through the artifact (seeded).
        let model = cfg.model.clone();
        let seed = cfg.seed as i32;
        let params = pool
            .submit(move |e| e.init(&model, seed))
            .recv()
            .map_err(|_| Error::Engine("init job lost".into()))??;
        let p = params.len();

        let availability = AvailabilityModel::new(cfg.ack_prob, cfg.straggler_prob, cfg.seed ^ 0xacc);
        let network = match cfg.network {
            NetworkKind::Ideal => NetworkModel::ideal(),
            NetworkKind::Simulated => NetworkModel::default(),
        };
        // Upload carrier: channels by default, real framed sockets on
        // request; a simulated network additionally re-orders deliveries
        // by virtual upload time. The aggregate is transport-invariant.
        let base: Box<dyn Transport> = match cfg.transport {
            TransportKind::InProcess => Box::new(InProcess::new()),
            TransportKind::Tcp | TransportKind::Uds => Box::new(Loopback::bind(cfg.transport)?),
        };
        let transport: Box<dyn Transport> = match cfg.network {
            NetworkKind::Ideal => base,
            NetworkKind::Simulated => Box::new(Simulated::new(base, network.clone())),
        };
        log::debug!("[{}] uploads travel via {}", cfg.label, transport.label());
        let recorder = RunRecorder::new(cfg.label.clone());
        let cfg_clients = cfg.clients;

        Ok(Server {
            cfg: Arc::new(cfg),
            pool,
            dataset,
            shards,
            eval_chunks,
            params: Arc::new(params),
            prev_broadcast: None,
            has_prev_broadcast: vec![false; cfg_clients],
            p,
            layers: mm.layers.clone(),
            ledger: CostLedger::new(),
            clock: VirtualClock::new(),
            availability,
            network,
            recorder,
            decode_scratch: DecodeScratch::default(),
            transport,
        })
    }

    pub fn config(&self) -> &ExperimentConfig {
        &self.cfg
    }

    pub fn params(&self) -> &[f32] {
        &self.params
    }

    /// ACK selection loop (Alg. 1/3 lines 9–14): walk a seeded permutation
    /// of the registry, requesting connections until `want` clients ACK.
    /// Returns `(completers, stragglers)` — stragglers ACKed (and therefore
    /// receive the broadcast, paying downlink) but miss the round deadline
    /// and are dropped before aggregation. Both lists sorted for
    /// deterministic aggregation order.
    fn select_clients(&self, round: usize, want: usize) -> (Vec<usize>, Vec<usize>) {
        let mut order: Vec<usize> = (0..self.cfg.clients).collect();
        let mut rng = Rng::new(self.cfg.seed).fork(round as u64).fork(0x5e1);
        rng.shuffle(&mut order);
        let mut completers = Vec::with_capacity(want);
        let mut stragglers = Vec::new();
        for &c in &order {
            if completers.len() + stragglers.len() >= want {
                break;
            }
            match self.availability.state(round as u64, c as u64) {
                ClientState::Available => completers.push(c),
                ClientState::Straggler => stragglers.push(c),
                ClientState::Offline => {}
            }
        }
        if completers.is_empty() {
            // Degenerate availability: fall back to the first candidate so a
            // run cannot deadlock (logged; the paper assumes full ACK).
            log::warn!("round {round}: no client completed; forcing client {}", order[0]);
            completers.push(order[0]);
            stragglers.retain(|&c| c != order[0]);
        }
        completers.sort_unstable();
        stragglers.sort_unstable();
        (completers, stragglers)
    }

    /// Encode this round's downlink broadcast through the codec. Returns
    /// the params clients receive plus the wire costs: delta bytes/nnz for
    /// a client that holds the previous broadcast state, dense bytes for
    /// one that must be caught up with the full model.
    ///
    /// Default: dense broadcast, clients share the global model verbatim.
    /// With `downlink_delta`: rounds after the first ship
    /// `w_t - w_{t-1}` through the configured encoding (sparse whenever a
    /// masked cohort left most coordinates untouched), and clients
    /// reconstruct `w_{t-1} + delta` — modeled here by decoding our own
    /// message, so lossy codecs affect the broadcast exactly as they would
    /// on a real wire. The delta stream is the canonical fleet-wide state:
    /// catch-up clients receive the same reconstructed params, just billed
    /// at dense cost.
    fn encode_broadcast(&mut self, t: usize) -> Result<(Arc<Vec<f32>>, BroadcastWire)> {
        let dense_bytes = wire_bytes(self.p, self.p, Encoding::Dense);
        if !self.cfg.downlink_delta {
            let wire = BroadcastWire {
                delta_bytes: dense_bytes,
                delta_nnz: self.p,
                dense_bytes,
                recon_err: 0.0,
            };
            return Ok((Arc::clone(&self.params), wire));
        }
        let (received, delta_bytes, delta_nnz, recon_err) = match self.prev_broadcast.take() {
            None => {
                // First broadcast: no client-side reference model yet. The
                // dense f32 wire is bit-exact, so reconstruction error is 0.
                let wire =
                    encode_update(BROADCAST_SENDER, t as u32, 0, &self.params, Encoding::Dense);
                (decode_update(&wire)?.into_dense(), wire.len(), self.p, 0.0f64)
            }
            Some(prev) => {
                let delta: Vec<f32> = self
                    .params
                    .iter()
                    .zip(prev.iter())
                    .map(|(new, old)| new - old)
                    .collect();
                let nnz = delta.iter().filter(|v| **v != 0.0).count();
                let wire =
                    encode_update(BROADCAST_SENDER, t as u32, 0, &delta, self.cfg.encoding);
                let decoded = decode_update(&wire)?.into_dense();
                let received: Vec<f32> = decoded
                    .iter()
                    .zip(prev.iter())
                    .map(|(d, old)| old + d)
                    .collect();
                // Fidelity check: the reconstructed broadcast may differ
                // from the true global model by (a) the codec's quantizer
                // half-step (zero for lossless encodings) and (b) f32
                // rounding of `old + d`. Anything beyond that bound is a
                // codec-contract violation and must fail loudly rather
                // than silently training the fleet on a drifted model.
                let recon_err = received
                    .iter()
                    .zip(self.params.iter())
                    .map(|(r, w)| (r - w).abs() as f64)
                    .fold(0.0f64, f64::max);
                let (lo, hi) = delta
                    .iter()
                    .fold((f32::INFINITY, f32::NEG_INFINITY), |(lo, hi), &d| {
                        (lo.min(d), hi.max(d))
                    });
                let half_step = if nnz == 0 {
                    0.0
                } else {
                    self.cfg.encoding.lossy_half_step(lo, hi) as f64
                };
                let max_abs = self
                    .params
                    .iter()
                    .map(|w| w.abs())
                    .fold(0.0f32, f32::max) as f64;
                let bound = half_step + 1e-5 * (1.0 + max_abs);
                if recon_err > bound {
                    return Err(Error::invalid(format!(
                        "round {t}: downlink delta reconstruction error {recon_err:.3e} exceeds \
                         the quantizer half-step bound {bound:.3e} ({})",
                        self.cfg.encoding.as_str()
                    )));
                }
                (received, wire.len(), nnz, recon_err)
            }
        };
        let received = Arc::new(received);
        self.prev_broadcast = Some(Arc::clone(&received));
        Ok((
            received,
            BroadcastWire {
                delta_bytes,
                delta_nnz,
                dense_bytes,
                recon_err,
            },
        ))
    }

    /// Execute one round (1-based `t`). Returns the round record.
    pub fn run_round(&mut self, t: usize) -> Result<RoundRecord> {
        let rate = self.cfg.sampling.rate(t);
        let want = self
            .cfg
            .sampling
            .num_clients(t, self.cfg.clients, self.cfg.min_clients);
        let (selected, stragglers) = self.select_clients(t, want);

        // Downlink: broadcast the global model to every client that ACKed —
        // stragglers included (their download is spent bandwidth even
        // though their update misses the deadline). Under delta encoding,
        // only clients that hold the previous broadcast state pay delta
        // bytes; the rest are caught up at dense cost.
        let (broadcast, wire) = self.encode_broadcast(t)?;
        let mut slowest_download = 0usize;
        let mut next_recipients = vec![false; self.cfg.clients];
        for &c in selected.iter().chain(&stragglers) {
            let (nnz, bytes) = if self.cfg.downlink_delta && self.has_prev_broadcast[c] {
                (wire.delta_nnz, wire.delta_bytes)
            } else {
                (self.p, wire.dense_bytes)
            };
            self.ledger.record_download_sparse(self.p, nnz, bytes);
            slowest_download = slowest_download.max(bytes);
            next_recipients[c] = true;
        }
        // Only this round's recipients hold w_t; everyone else goes stale
        // and pays dense next time they are sampled.
        self.has_prev_broadcast = next_recipients;
        if !stragglers.is_empty() {
            log::debug!("round {t}: {} stragglers dropped past deadline", stragglers.len());
        }

        // Fan out local training. Jobs are scratch-aware: each worker's
        // long-lived buffers back the masking + encode temporaries. The
        // encoded payload leaves through the round's transport sink the
        // moment it exists; only sideband metadata (loss, nnz, byte count)
        // returns through the pool channel.
        let sink = self.transport.sink();
        let jobs: Vec<_> = selected
            .iter()
            .map(|&cid| {
                let job = ClientJob {
                    client_id: cid,
                    round: t,
                    dataset: Arc::clone(&self.dataset),
                    shard: self.shards[cid].clone(),
                    global: Arc::clone(&broadcast),
                    cfg: Arc::clone(&self.cfg),
                };
                let sink = Arc::clone(&sink);
                move |e: &crate::runtime::engine::Engine,
                      s: &mut crate::runtime::pool::WorkerScratch|
                      -> Result<(f32, usize, usize)> {
                    let outcome = job.run(e, s)?;
                    let bytes = outcome.payload.len();
                    sink.send(outcome.payload)?;
                    Ok((outcome.train_loss, outcome.nnz, bytes))
                }
            })
            .collect();

        // Streaming aggregation: each completed job pushes its payload into
        // the transport, and `drain_round_uploads` runs a select-style wait
        // over the pool-result channel and the wire — folding each payload
        // (borrowed view, sparse bodies stay sparse) the moment it lands
        // while surfacing any job's concrete error within a poll tick
        // instead of after the upload timeout. Wire updates are matched to
        // the cohort by their own header, so out-of-order socket delivery
        // is fine; metadata is parked per input index so the ledger and
        // logs stay in deterministic client-id order.
        let n_jobs = jobs.len();
        self.transport.begin_round(n_jobs);
        let mut agg =
            make_aggregator(self.cfg.aggregator, self.cfg.mask_target, &broadcast, &self.layers)?;
        let tolerate_strays = self.transport.accepts_foreign_peers();
        let results = self.pool.map_unordered_with(jobs);
        let metas = drain_round_uploads(
            self.transport.as_mut(),
            &results,
            agg.as_mut(),
            &mut self.decode_scratch,
            &selected,
            t,
            self.p,
            tolerate_strays,
            DEFAULT_UPLOAD_TIMEOUT,
        )?;
        self.params = Arc::new(agg.finish()?);

        // Uplink accounting + virtual time, in client-id (input) order.
        let mut upload_sizes = Vec::with_capacity(n_jobs);
        let mut loss_sum = 0.0f64;
        for &(train_loss, nnz, bytes) in &metas {
            self.ledger.record_upload(self.p, nnz, bytes);
            upload_sizes.push(bytes);
            loss_sum += train_loss as f64;
        }
        let compute_s = selected
            .iter()
            .map(|&c| {
                self.availability
                    .compute_time(t as u64, c as u64, self.cfg.local_epochs)
            })
            .fold(0.0f64, f64::max);
        self.clock.advance(self.network.download_time(slowest_download));
        self.clock.advance(compute_s);
        self.clock
            .advance(self.network.upload_round_time(&upload_sizes));

        let train_loss = loss_sum / n_jobs as f64;

        // Periodic evaluation.
        let eval = if t % self.cfg.eval_every == 0 || t == self.cfg.rounds {
            Some(self.evaluate()?)
        } else {
            None
        };

        let rec = RoundRecord {
            round: t,
            sample_rate: rate,
            clients: selected.len(),
            train_loss,
            test_loss: eval.map(|e| e.mean_loss()).unwrap_or(f64::NAN),
            test_accuracy: eval.map(|e| e.accuracy()).unwrap_or(f64::NAN),
            test_perplexity: eval.map(|e| e.perplexity()).unwrap_or(f64::NAN),
            uplink_units: self.ledger.uplink_units,
            uplink_bytes: self.ledger.uplink_bytes,
            downlink_bytes: self.ledger.downlink_bytes,
            downlink_recon_err: wire.recon_err,
            virtual_time_s: self.clock.now(),
        };
        self.recorder.push(rec.clone());
        Ok(rec)
    }

    /// Evaluate the current global model over the pre-built eval chunks,
    /// fanned out across the pool.
    pub fn evaluate(&self) -> Result<EvalSums> {
        let jobs: Vec<_> = (0..self.eval_chunks.len())
            .map(|i| {
                let chunks = Arc::clone(&self.eval_chunks);
                let params = Arc::clone(&self.params);
                let model = self.cfg.model.clone();
                move |e: &crate::runtime::engine::Engine| e.eval_chunk(&model, &params, &chunks[i])
            })
            .collect();
        let mut total = EvalSums::default();
        for s in self.pool.map(jobs)? {
            total.add(s?);
        }
        Ok(total)
    }

    /// Run all configured rounds.
    pub fn run(mut self) -> Result<ServerOutcome> {
        let rounds = self.cfg.rounds;
        for t in 1..=rounds {
            let rec = self.run_round(t)?;
            log::info!(
                "[{}] round {t}/{rounds}: clients={} rate={:.3} loss={:.4} acc={:.4} cost={:.2}u",
                self.cfg.label,
                rec.clients,
                rec.sample_rate,
                rec.train_loss,
                rec.test_accuracy,
                rec.uplink_units,
            );
        }
        Ok(ServerOutcome {
            recorder: self.recorder,
            final_params: Arc::try_unwrap(self.params).unwrap_or_else(|arc| (*arc).clone()),
            ledger: self.ledger,
        })
    }
}

#[cfg(test)]
mod tests {
    //! Engine-free tests of the round drain loop: `drain_round_uploads`
    //! takes only channels, a transport, and an aggregator, so the
    //! dead-client regression (ROADMAP item (c)) is pinned here without
    //! PJRT artifacts.

    use super::*;
    use crate::config::experiment::AggregatorKind;
    use crate::fl::masking::MaskTarget;
    use crate::runtime::manifest::LayerInfo;
    use crate::transport::network::NetworkModel;
    use std::sync::mpsc::channel;

    const P: usize = 16;

    fn layers() -> Vec<LayerInfo> {
        vec![LayerInfo {
            name: "w".into(),
            shape: vec![P],
            offset: 0,
            size: P,
            masked: true,
        }]
    }

    fn payload_for(client: u32, round: u32) -> Vec<u8> {
        let mut params = vec![0.0f32; P];
        params[client as usize] = 1.0 + client as f32;
        encode_update(client, round, 10 + client, &params, Encoding::Auto)
    }

    fn fresh_agg() -> Box<dyn Aggregator> {
        let broadcast = vec![0.0f32; P];
        make_aggregator(AggregatorKind::FedAvg, MaskTarget::Weights, &broadcast, &layers())
            .unwrap()
    }

    /// Build a simulated-network transport over in-process channels — the
    /// configuration whose first recv used to barrier on the whole cohort
    /// and wait out the 300 s upload timeout when a client died.
    fn simulated_transport() -> Simulated {
        Simulated::new(Box::new(InProcess::new()), NetworkModel::default())
    }

    /// Headline regression: under `network = "simulated"`, a client job
    /// that dies (here: its worker panics before sending anything) fails
    /// the round with the pool's error in well under the upload timeout —
    /// the old drain waited out the full 300 s first.
    #[test]
    fn dead_client_fails_the_round_immediately_not_after_the_upload_timeout() {
        let mut transport = simulated_transport();
        let sink = transport.sink();
        let selected = vec![0usize, 1];
        transport.begin_round(selected.len());
        let (tx, results) = channel::<(usize, Result<JobMeta>)>();

        // client 0 completes normally: payload over the wire + metadata
        let payload = payload_for(0, 1);
        let bytes = payload.len();
        sink.send(payload).unwrap();
        tx.send((0, Ok((0.5, 1, bytes)))).unwrap();

        // client 1 "panics": its worker thread unwinds, dropping the reply
        // sender without ever sending a payload or metadata
        let tx1 = tx.clone();
        let victim = std::thread::spawn(move || {
            let _held_until_unwind = tx1;
            panic!("client 1 panicked mid-round");
        });
        assert!(victim.join().is_err());
        drop(tx);

        let started = Instant::now();
        let mut agg = fresh_agg();
        let err = drain_round_uploads(
            &mut transport,
            &results,
            agg.as_mut(),
            &mut DecodeScratch::default(),
            &selected,
            1,
            P,
            false,
            DEFAULT_UPLOAD_TIMEOUT,
        )
        .unwrap_err();
        let elapsed = started.elapsed();
        assert!(matches!(err, Error::Engine(_)), "{err}");
        assert!(
            elapsed < Duration::from_secs(5),
            "dead client took {elapsed:?} to surface (budget 5 s, old behavior 300 s)"
        );
    }

    /// A job that returns a concrete error (rather than dying) surfaces
    /// that exact error immediately, even though its upload never arrives
    /// and the simulated network is still barriering on the cohort.
    #[test]
    fn failed_job_error_beats_the_wire_timeout_and_names_the_cause() {
        let mut transport = simulated_transport();
        let sink = transport.sink();
        let selected = vec![0usize, 1];
        transport.begin_round(selected.len());
        let (tx, results) = channel::<(usize, Result<JobMeta>)>();

        let payload = payload_for(0, 1);
        let bytes = payload.len();
        sink.send(payload).unwrap();
        tx.send((0, Ok((0.5, 1, bytes)))).unwrap();
        tx.send((1, Err(Error::Engine("client 1 exploded".into())))).unwrap();

        let started = Instant::now();
        let mut agg = fresh_agg();
        let err = drain_round_uploads(
            &mut transport,
            &results,
            agg.as_mut(),
            &mut DecodeScratch::default(),
            &selected,
            1,
            P,
            false,
            DEFAULT_UPLOAD_TIMEOUT,
        )
        .unwrap_err();
        assert!(err.to_string().contains("client 1 exploded"), "{err}");
        assert!(started.elapsed() < Duration::from_secs(5));
    }

    /// Healthy rounds still work through the polling drain: payloads and
    /// metadata arriving in scrambled, interleaved order all fold, and the
    /// metadata comes back in input order.
    #[test]
    fn drain_folds_cohort_with_scrambled_arrival_orders() {
        for use_simulated in [false, true] {
            let mut transport: Box<dyn Transport> = if use_simulated {
                Box::new(simulated_transport())
            } else {
                Box::new(InProcess::new())
            };
            let sink = transport.sink();
            let selected = vec![0usize, 1, 2];
            transport.begin_round(selected.len());
            let (tx, results) = channel::<(usize, Result<JobMeta>)>();

            // metadata for 2 lands before its payload; payload order 1,2,0
            let payloads: Vec<Vec<u8>> =
                (0..3).map(|c| payload_for(c as u32, 7)).collect();
            tx.send((2, Ok((0.2, 1, payloads[2].len())))).unwrap();
            sink.send(payloads[1].clone()).unwrap();
            sink.send(payloads[2].clone()).unwrap();
            tx.send((0, Ok((0.0, 1, payloads[0].len())))).unwrap();
            sink.send(payloads[0].clone()).unwrap();
            tx.send((1, Ok((0.1, 1, payloads[1].len())))).unwrap();
            drop(tx);

            let mut agg = fresh_agg();
            let metas = drain_round_uploads(
                transport.as_mut(),
                &results,
                agg.as_mut(),
                &mut DecodeScratch::default(),
                &selected,
                7,
                P,
                false,
                Duration::from_secs(30),
            )
            .unwrap();
            assert_eq!(metas.len(), 3);
            for (i, (loss, nnz, bytes)) in metas.iter().enumerate() {
                assert_eq!(*loss, 0.1 * i as f32);
                assert_eq!(*nnz, 1);
                assert_eq!(*bytes, payloads[i].len());
            }
            // the fold saw all three contributions
            let out = agg.finish().unwrap();
            let total: u32 = 10 + 11 + 12;
            for c in 0..3usize {
                let want = (1.0 + c as f32) * (10 + c as u32) as f32 / total as f32;
                assert!(
                    (out[c] - want).abs() < 1e-6,
                    "coord {c}: {} vs {want} (simulated={use_simulated})",
                    out[c]
                );
            }
        }
    }

    /// An upload that never arrives (job reported fine but the payload was
    /// lost) times out with a typed transport error naming the missing
    /// clients — using a short timeout to keep the test fast.
    #[test]
    fn missing_upload_times_out_with_missing_clients_named() {
        let mut transport = InProcess::new();
        let selected = vec![4usize, 9];
        transport.begin_round(selected.len());
        let (tx, results) = channel::<(usize, Result<JobMeta>)>();
        tx.send((0, Ok((0.0, 1, 10)))).unwrap();
        tx.send((1, Ok((0.0, 1, 10)))).unwrap();
        drop(tx);

        let mut agg = fresh_agg();
        let err = drain_round_uploads(
            &mut transport,
            &results,
            agg.as_mut(),
            &mut DecodeScratch::default(),
            &selected,
            1,
            P,
            false,
            Duration::from_millis(150),
        )
        .unwrap_err();
        assert!(matches!(err, Error::Transport(_)), "{err}");
        let msg = err.to_string();
        assert!(msg.contains("timed out") && msg.contains('4') && msg.contains('9'), "{msg}");
    }

    /// On a closed (in-process) wire an invalid payload fails the round
    /// precisely; on an open wire it is dropped and the genuine upload
    /// still folds.
    #[test]
    fn stray_payload_policy_follows_the_transport() {
        // closed wire: wrong-round payload is an internal bug -> error
        let mut transport = InProcess::new();
        let sink = transport.sink();
        let selected = vec![0usize];
        transport.begin_round(1);
        let (tx, results) = channel::<(usize, Result<JobMeta>)>();
        let good = payload_for(0, 3);
        tx.send((0, Ok((0.0, 1, good.len())))).unwrap();
        sink.send(payload_for(0, 99)).unwrap();
        let mut agg = fresh_agg();
        let err = drain_round_uploads(
            &mut transport,
            &results,
            agg.as_mut(),
            &mut DecodeScratch::default(),
            &selected,
            3,
            P,
            false,
            Duration::from_secs(5),
        )
        .unwrap_err();
        assert!(err.to_string().contains("round"), "{err}");

        // open wire: the stray is dropped, the genuine upload folds
        let mut transport = InProcess::new();
        let sink = transport.sink();
        transport.begin_round(1);
        let (tx, results) = channel::<(usize, Result<JobMeta>)>();
        tx.send((0, Ok((0.0, 1, good.len())))).unwrap();
        drop(tx);
        sink.send(payload_for(0, 99)).unwrap();
        sink.send(good).unwrap();
        let mut agg = fresh_agg();
        let metas = drain_round_uploads(
            &mut transport,
            &results,
            agg.as_mut(),
            &mut DecodeScratch::default(),
            &selected,
            3,
            P,
            true,
            Duration::from_secs(5),
        )
        .unwrap();
        assert_eq!(metas.len(), 1);
        assert_eq!(agg.folded(), 1);
    }
}
