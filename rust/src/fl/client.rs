//! Simulated on-device client (Alg. 2 / Alg. 4, "Run on the k-th client").
//!
//! A [`ClientJob`] carries everything one selected client needs for a
//! round: its data shard (via a shared `Arc<Dataset>`), the run
//! parameters, and — since the full-duplex session refactor — a handle on
//! the transport's **downlink half** instead of the broadcast itself.
//! [`ClientJob::run`] executes on an engine-pool worker: it first
//! *receives the round's encoded broadcast from the wire*
//! ([`receive_broadcast`]: decode, and under `downlink_delta` reconstruct
//! `w_{t-1} + delta` against the reference state it holds), then runs
//! local epochs of scanned mini-batch SGD through the train artifact, the
//! configured masking, and wire encoding. Everything is seeded from
//! (experiment seed, round, client id), so a round's outcome is
//! independent of worker scheduling — and because every transport delivers
//! the same broadcast bytes, it is transport-independent too.

use std::ops::Range;
use std::sync::Arc;
use std::time::Duration;

use crate::config::experiment::ExperimentConfig;
use crate::data::{batcher, Dataset};
use crate::fl::masking::{random_mask_rust, MaskEngine, MaskPolicy};
use crate::fl::pipeline::mask_stream_selective;
use crate::runtime::engine::Engine;
use crate::runtime::pool::WorkerScratch;
use crate::sim::rng::Rng;
use crate::transport::codec::{
    decode_update, encode_masked, encode_update_cached_into, BROADCAST_DELTA, BROADCAST_FULL,
    BROADCAST_SENDER,
};
use crate::transport::link::{DownlinkSource, DEFAULT_UPLOAD_TIMEOUT};
use crate::transport::session::IndexCache;
use crate::util::error::{Error, Result};

/// How long a client job waits for its round broadcast. Mirrors the
/// upload timeout: it only trips when the server died mid-round.
pub const DOWNLINK_TIMEOUT: Duration = DEFAULT_UPLOAD_TIMEOUT;

/// A client's data shard reference.
#[derive(Debug, Clone)]
pub enum ShardRef {
    Image(Vec<usize>),
    Text(Range<usize>),
}

impl ShardRef {
    /// Local sample count n_i (FedAvg weight). For text shards this is the
    /// number of training windows.
    pub fn n_samples(&self, seq_window: usize) -> usize {
        match self {
            ShardRef::Image(idx) => idx.len(),
            ShardRef::Text(range) => (range.end - range.start) / seq_window,
        }
    }
}

/// Receive and materialize one round's broadcast from the downlink wire —
/// the client half of the delta-downlink protocol, engine-free by design
/// so the reconstruction contract is unit-testable without PJRT.
///
/// Validation before use: the message must come from the server
/// ([`BROADCAST_SENDER`]), name this `round`, and its semantics flag must
/// match what the server believes this client holds — [`BROADCAST_DELTA`]
/// if and only if `reference` is `Some` (the previous broadcast the
/// client kept). A mismatch means server and client disagree about client
/// state and training on the result would silently drift, so it fails
/// loudly instead.
///
/// Reconstruction is exactly the server's canonical arithmetic
/// (`old + d` per coordinate, f32), so every client's materialized model
/// is bitwise identical to the server's `received` reference — which is
/// what keeps aggregation transport-invariant even under lossy downlink
/// encodings.
pub fn receive_broadcast(
    downlink: &dyn DownlinkSource,
    client: u32,
    round: u32,
    reference: Option<&[f32]>,
    timeout: Duration,
) -> Result<Vec<f32>> {
    let bytes = downlink.recv(client, timeout)?;
    let msg = decode_update(&bytes)?;
    if msg.client != BROADCAST_SENDER {
        return Err(Error::invalid(format!(
            "client {client}: broadcast names sender {}, not the server",
            msg.client
        )));
    }
    if msg.round != round {
        return Err(Error::invalid(format!(
            "client {client}: broadcast is for round {}, expected round {round}",
            msg.round
        )));
    }
    match (msg.n_samples, reference) {
        (BROADCAST_FULL, None) => Ok(msg.into_dense()),
        (BROADCAST_DELTA, Some(prev)) => {
            if msg.p != prev.len() {
                return Err(Error::invalid(format!(
                    "client {client}: delta broadcast carries {} params, reference holds {}",
                    msg.p,
                    prev.len()
                )));
            }
            let delta = msg.into_dense();
            Ok(delta.iter().zip(prev.iter()).map(|(d, old)| old + d).collect())
        }
        (BROADCAST_FULL, Some(_)) => Err(Error::invalid(format!(
            "client {client}: received a full broadcast but holds delta reference state \
             (server/client state disagreement)"
        ))),
        (BROADCAST_DELTA, None) => Err(Error::invalid(format!(
            "client {client}: received a delta broadcast with no reference state to apply it to"
        ))),
        (other, _) => Err(Error::invalid(format!(
            "client {client}: unknown broadcast semantics flag {other}"
        ))),
    }
}

/// What a client sends back to the server: the encoded wire message plus
/// sideband metadata that never crosses the network.
///
/// The dense parameter vector is gone from *both* directions of the
/// client↔server path — `payload` (an encoded
/// [`crate::transport::codec::WireUpdate`]: header + masked sparse / dense /
/// quantized body) is the only carrier of the update, and the broadcast
/// the client trained from arrived the same way. The FedAvg weight n_i
/// rides in the wire header, exactly like a real deployment. The
/// server-side job wrapper ships `payload` through the round's
/// [`UploadSink`](crate::transport::link::UploadSink) — an in-process
/// channel by default, the client's persistent authenticated TCP/UDS
/// session under `--transport tcp|uds` — so under a socket transport these
/// bytes genuinely cross a kernel socket before the server sees them.
#[derive(Debug, Clone)]
pub struct LocalOutcome {
    pub client: usize,
    /// Encoded upload; `payload.len()` is the exact uplink byte cost.
    pub payload: Vec<u8>,
    /// Mean local training loss over the final epoch (server-side metric,
    /// not part of the aggregated update).
    pub train_loss: f32,
    /// Non-zero entries in the masked vector (unit-cost accounting; for
    /// unmasked uploads this is the full model size by protocol convention).
    pub nnz: usize,
}

/// One selected client's work for one round.
pub struct ClientJob {
    pub client_id: usize,
    pub round: usize,
    pub dataset: Arc<Dataset>,
    pub shard: ShardRef,
    /// Where this round's encoded broadcast arrives (the transport's
    /// downlink half).
    pub downlink: Arc<dyn DownlinkSource>,
    /// The previous broadcast this client holds — the reference a delta
    /// downlink reconstructs against; `None` means the server owes it a
    /// full (dense-cost) broadcast this round.
    pub reference: Option<Arc<Vec<f32>>>,
    /// The session's cross-round index cache (wire v3): the support of
    /// this client's last accepted upload, to encode a `SparseCached`
    /// set-delta against. The same `Arc` the server decodes with — handed
    /// over at broadcast by the round driver. `None` (always, for
    /// encodings that never use the cache) forces a stateless full-index
    /// send.
    pub index_cache: Option<Arc<IndexCache>>,
    pub cfg: Arc<ExperimentConfig>,
}

impl ClientJob {
    /// Substream for (round, client, purpose).
    fn rng(&self, purpose: u64) -> Rng {
        Rng::new(self.cfg.seed)
            .fork(self.round as u64)
            .fork(self.client_id as u64)
            .fork(purpose)
    }

    /// Run the local update on an engine worker. `scratch` is the worker's
    /// long-lived buffer arena (mask deltas, the fused mask→encode stream,
    /// encode temporaries, and the shared payload-frame pool), so a
    /// steady-state round allocates nothing per client on the mask/encode
    /// path beyond the materialized broadcast.
    pub fn run(&self, engine: &Engine, scratch: &mut WorkerScratch) -> Result<LocalOutcome> {
        let model = &self.cfg.model;
        let mm = engine.model(model)?.clone();

        // Downlink: pull this round's encoded broadcast off the wire and
        // materialize the global model (dense decode, or delta
        // reconstruction against the held reference).
        let global = receive_broadcast(
            self.downlink.as_ref(),
            self.client_id as u32,
            self.round as u32,
            self.reference.as_deref().map(Vec::as_slice),
            DOWNLINK_TIMEOUT,
        )?;
        let mut params = global.clone();
        let mut last_loss = 0.0f32;

        // E local epochs; each epoch reshuffles the shard and streams the
        // chunks through the scanned train artifact.
        for epoch in 0..self.cfg.local_epochs {
            let mut rng = self.rng(epoch as u64);
            let chunks = match (&*self.dataset, &self.shard) {
                (Dataset::Image { train, .. }, ShardRef::Image(idx)) => {
                    batcher::image_train_chunks(train, idx, &mm, &mut rng)?
                }
                (Dataset::Text { train, .. }, ShardRef::Text(range)) => {
                    batcher::text_train_chunks(train, range, &mm, &mut rng)?
                }
                _ => return Err(Error::invalid("dataset/shard kind mismatch")),
            };
            let mut loss_acc = 0.0f32;
            for chunk in &chunks {
                let (np, loss) = engine.train_epoch(model, &params, chunk, self.cfg.lr)?;
                params = np;
                loss_acc += loss;
            }
            last_loss = loss_acc / chunks.len().max(1) as f32;
        }

        // Masking (Alg. 2 line 9-12 / Alg. 4 line 9-14) + wire encoding.
        //
        // The masked (sparse) update is what crosses the wire. The Delta
        // mask-target reconstruction (dropped weights revert to their
        // broadcast values) happens server-side after decode — the server
        // knows w_old, it sent it. Lossy codecs (q8) need no special-casing
        // anymore: the server aggregates exactly what it decodes.
        // Unmasked uploads are a full model by definition (incidental exact
        // zeros in trained weights are not a sparsity the protocol exploits).
        //
        // The exact-rust selective path is *fused*: the masker's top-k
        // partition feeds kept (index, value) pairs straight into the
        // worker's `MaskedStream` (census sideband accumulated in the same
        // pass) and `encode_masked` writes the frame from the stream — no
        // dense masked vector, no second census walk. Every path encodes
        // into a frame checked out of the shared `BufferPool`, returned by
        // the round driver after the fold, so a steady-state round performs
        // zero encode-side heap allocation (pinned by tests/alloc_count.rs).
        let n_samples = self.shard.n_samples(mm.x_elem_shape.first().copied().unwrap_or(1) + 1) as u32;
        let mut payload = scratch.buffers.take();
        let nnz = match self.cfg.masking {
            MaskPolicy::Selective { gamma, engine: MaskEngine::Rust, scope } => {
                mask_stream_selective(
                    &params,
                    &global,
                    gamma,
                    &mm.layers,
                    scope,
                    &mut scratch.mask,
                    &mut scratch.stream,
                )?;
                encode_masked(
                    &mut scratch.encode,
                    &mut payload,
                    self.client_id as u32,
                    self.round as u32,
                    n_samples,
                    &scratch.stream,
                    self.cfg.encoding,
                    self.index_cache.as_deref(),
                )?;
                scratch.stream.nnz()
            }
            _ => {
                let masked = match self.cfg.masking {
                    MaskPolicy::None => params,
                    MaskPolicy::Random { gamma } => {
                        let mut rng = self.rng(0xa5);
                        random_mask_rust(&params, gamma, &mm.layers, &mut rng)
                    }
                    MaskPolicy::Selective { gamma, .. } => {
                        engine.mask(model, &params, &global, gamma)?
                    }
                };
                let nnz = match self.cfg.masking {
                    MaskPolicy::None => masked.len(),
                    _ => masked.iter().filter(|v| **v != 0.0).count(),
                };
                encode_update_cached_into(
                    &mut scratch.encode,
                    &mut payload,
                    self.client_id as u32,
                    self.round as u32,
                    n_samples,
                    &masked,
                    self.cfg.encoding,
                    self.index_cache.as_deref(),
                );
                nnz
            }
        };

        Ok(LocalOutcome {
            client: self.client_id,
            payload,
            train_loss: last_loss,
            nnz,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::transport::codec::{encode_update, Encoding};
    use crate::transport::link::{InProcess, Transport};

    #[test]
    fn shard_sample_counts() {
        let img = ShardRef::Image((0..37).collect());
        assert_eq!(img.n_samples(33), 37);
        let txt = ShardRef::Text(100..430);
        assert_eq!(txt.n_samples(33), 10);
    }

    fn wired(client: u32, msg: Vec<u8>) -> Arc<dyn DownlinkSource> {
        let mut t = InProcess::new();
        t.register_clients(&[client]).unwrap();
        t.send_downlink(client, Arc::new(msg)).unwrap();
        t.downlink()
    }

    const T: Duration = Duration::from_secs(2);

    #[test]
    fn full_broadcast_decodes_bitwise() {
        let params = vec![0.5f32, -1.25, 0.0, 3.5];
        let msg = encode_update(BROADCAST_SENDER, 4, BROADCAST_FULL, &params, Encoding::Dense);
        let dl = wired(7, msg);
        let got = receive_broadcast(dl.as_ref(), 7, 4, None, T).unwrap();
        assert_eq!(got, params, "dense f32 downlink must be bit-exact");
    }

    #[test]
    fn delta_broadcast_reconstructs_with_the_servers_arithmetic() {
        let prev = vec![1.0f32, 2.0, -3.0, 0.25];
        let delta = vec![0.5f32, 0.0, 1.5, -0.25];
        for &enc in Encoding::ALL {
            let msg = encode_update(BROADCAST_SENDER, 9, BROADCAST_DELTA, &delta, enc);
            let dl = wired(3, msg.clone());
            let got = receive_broadcast(dl.as_ref(), 3, 9, Some(&prev), T).unwrap();
            // the canonical reconstruction: decode our own message, add
            let decoded = decode_update(&msg).unwrap().into_dense();
            let want: Vec<f32> =
                decoded.iter().zip(prev.iter()).map(|(d, old)| old + d).collect();
            assert_eq!(got, want, "{enc:?}");
        }
    }

    #[test]
    fn state_disagreements_fail_loudly() {
        let prev = vec![1.0f32, 2.0];
        // full broadcast but the client holds reference state
        let full = encode_update(BROADCAST_SENDER, 1, BROADCAST_FULL, &prev, Encoding::Dense);
        let err = receive_broadcast(wired(0, full).as_ref(), 0, 1, Some(&prev), T).unwrap_err();
        assert!(err.to_string().contains("disagreement"), "{err}");
        // delta broadcast but the client holds nothing
        let delta = encode_update(BROADCAST_SENDER, 1, BROADCAST_DELTA, &prev, Encoding::Dense);
        let err = receive_broadcast(wired(0, delta).as_ref(), 0, 1, None, T).unwrap_err();
        assert!(err.to_string().contains("no reference"), "{err}");
        // dimension mismatch between delta and reference
        let delta3 =
            encode_update(BROADCAST_SENDER, 1, BROADCAST_DELTA, &[1.0, 2.0, 3.0], Encoding::Dense);
        let err = receive_broadcast(wired(0, delta3).as_ref(), 0, 1, Some(&prev), T).unwrap_err();
        assert!(err.to_string().contains("reference holds"), "{err}");
    }

    #[test]
    fn wrong_round_and_wrong_sender_are_rejected() {
        let params = vec![1.0f32];
        let msg = encode_update(BROADCAST_SENDER, 5, BROADCAST_FULL, &params, Encoding::Dense);
        let err = receive_broadcast(wired(0, msg).as_ref(), 0, 6, None, T).unwrap_err();
        assert!(err.to_string().contains("round"), "{err}");
        // an upload masquerading as a broadcast names a real client id
        let msg = encode_update(12, 5, BROADCAST_FULL, &params, Encoding::Dense);
        let err = receive_broadcast(wired(0, msg).as_ref(), 0, 5, None, T).unwrap_err();
        assert!(err.to_string().contains("sender"), "{err}");
    }
}
