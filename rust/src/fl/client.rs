//! Simulated on-device client (Alg. 2 / Alg. 4, "Run on the k-th client").
//!
//! A [`ClientJob`] carries everything one selected client needs for a round:
//! the broadcast global model, its data shard (via a shared `Arc<Dataset>`),
//! and the run parameters. [`ClientJob::run`] executes on an engine-pool
//! worker: local epochs of scanned mini-batch SGD through the train
//! artifact, then the configured masking, then wire encoding. Everything is
//! seeded from (experiment seed, round, client id), so a round's outcome is
//! independent of worker scheduling.

use std::ops::Range;
use std::sync::Arc;

use crate::config::experiment::ExperimentConfig;
use crate::data::{batcher, Dataset};
use crate::fl::masking::{random_mask_rust, selective_mask_rust_with, MaskEngine, MaskPolicy};
use crate::runtime::engine::Engine;
use crate::runtime::pool::WorkerScratch;
use crate::sim::rng::Rng;
use crate::transport::codec::encode_update_with;
use crate::util::error::{Error, Result};

/// A client's data shard reference.
#[derive(Debug, Clone)]
pub enum ShardRef {
    Image(Vec<usize>),
    Text(Range<usize>),
}

impl ShardRef {
    /// Local sample count n_i (FedAvg weight). For text shards this is the
    /// number of training windows.
    pub fn n_samples(&self, seq_window: usize) -> usize {
        match self {
            ShardRef::Image(idx) => idx.len(),
            ShardRef::Text(range) => (range.end - range.start) / seq_window,
        }
    }
}

/// What a client sends back to the server: the encoded wire message plus
/// sideband metadata that never crosses the network.
///
/// Since the transport refactor the dense parameter vector is gone from the
/// client->server path — `payload` (an encoded
/// [`crate::transport::codec::WireUpdate`]: header + masked sparse / dense /
/// quantized body) is the only carrier of the update, and the server
/// decodes it before aggregating. The FedAvg weight n_i rides in the wire
/// header, exactly like a real deployment. The server-side job wrapper
/// ships `payload` through the round's
/// [`UploadSink`](crate::transport::link::UploadSink) — an in-process
/// channel by default, a framed TCP/UDS socket under `--transport tcp|uds`
/// — so under a socket transport these bytes genuinely cross a kernel
/// socket before the server sees them.
#[derive(Debug, Clone)]
pub struct LocalOutcome {
    pub client: usize,
    /// Encoded upload; `payload.len()` is the exact uplink byte cost.
    pub payload: Vec<u8>,
    /// Mean local training loss over the final epoch (server-side metric,
    /// not part of the aggregated update).
    pub train_loss: f32,
    /// Non-zero entries in the masked vector (unit-cost accounting; for
    /// unmasked uploads this is the full model size by protocol convention).
    pub nnz: usize,
}

/// One selected client's work for one round.
pub struct ClientJob {
    pub client_id: usize,
    pub round: usize,
    pub dataset: Arc<Dataset>,
    pub shard: ShardRef,
    pub global: Arc<Vec<f32>>,
    pub cfg: Arc<ExperimentConfig>,
}

impl ClientJob {
    /// Substream for (round, client, purpose).
    fn rng(&self, purpose: u64) -> Rng {
        Rng::new(self.cfg.seed)
            .fork(self.round as u64)
            .fork(self.client_id as u64)
            .fork(purpose)
    }

    /// Run the local update on an engine worker. `scratch` is the worker's
    /// long-lived buffer arena (mask deltas, encode temporaries), so a
    /// steady-state round allocates nothing per client beyond the payload
    /// itself.
    pub fn run(&self, engine: &Engine, scratch: &mut WorkerScratch) -> Result<LocalOutcome> {
        let model = &self.cfg.model;
        let mm = engine.model(model)?.clone();
        let mut params = (*self.global).clone();
        let mut last_loss = 0.0f32;

        // E local epochs; each epoch reshuffles the shard and streams the
        // chunks through the scanned train artifact.
        for epoch in 0..self.cfg.local_epochs {
            let mut rng = self.rng(epoch as u64);
            let chunks = match (&*self.dataset, &self.shard) {
                (Dataset::Image { train, .. }, ShardRef::Image(idx)) => {
                    batcher::image_train_chunks(train, idx, &mm, &mut rng)?
                }
                (Dataset::Text { train, .. }, ShardRef::Text(range)) => {
                    batcher::text_train_chunks(train, range, &mm, &mut rng)?
                }
                _ => return Err(Error::invalid("dataset/shard kind mismatch")),
            };
            let mut loss_acc = 0.0f32;
            for chunk in &chunks {
                let (np, loss) = engine.train_epoch(model, &params, chunk, self.cfg.lr)?;
                params = np;
                loss_acc += loss;
            }
            last_loss = loss_acc / chunks.len().max(1) as f32;
        }

        // Masking (Alg. 2 line 9-12 / Alg. 4 line 9-14).
        let masked = match self.cfg.masking {
            MaskPolicy::None => params,
            MaskPolicy::Random { gamma } => {
                let mut rng = self.rng(0xa5);
                random_mask_rust(&params, gamma, &mm.layers, &mut rng)
            }
            MaskPolicy::Selective { gamma, engine: me, scope } => match me {
                MaskEngine::Hlo => engine.mask(model, &params, &self.global, gamma)?,
                MaskEngine::Rust => selective_mask_rust_with(
                    &params,
                    &self.global,
                    gamma,
                    &mm.layers,
                    scope,
                    &mut scratch.mask,
                ),
            },
        };

        // The masked (sparse) vector is what crosses the wire. The Delta
        // mask-target reconstruction (dropped weights revert to their
        // broadcast values) happens server-side after decode — the server
        // knows w_old, it sent it. Lossy codecs (q8) need no special-casing
        // anymore: the server aggregates exactly what it decodes.
        // Unmasked uploads are a full model by definition (incidental exact
        // zeros in trained weights are not a sparsity the protocol exploits).
        let nnz = match self.cfg.masking {
            MaskPolicy::None => masked.len(),
            _ => masked.iter().filter(|v| **v != 0.0).count(),
        };
        let n_samples = self.shard.n_samples(mm.x_elem_shape.first().copied().unwrap_or(1) + 1) as u32;
        let payload = encode_update_with(
            &mut scratch.encode,
            self.client_id as u32,
            self.round as u32,
            n_samples,
            &masked,
            self.cfg.encoding,
        );

        Ok(LocalOutcome {
            client: self.client_id,
            payload,
            train_loss: last_loss,
            nnz,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shard_sample_counts() {
        let img = ShardRef::Image((0..37).collect());
        assert_eq!(img.n_samples(33), 37);
        let txt = ShardRef::Text(100..430);
        assert_eq!(txt.n_samples(33), 10);
    }
}
