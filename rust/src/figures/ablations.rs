//! Ablation studies for the design choices DESIGN.md calls out.
//!
//! `fedmask figure ablations` runs four small studies on MNIST/LeNet:
//!
//! 1. **mask-target** — paper-literal weight zeroing (`weights`) vs the
//!    sparse-delta reading (`delta`) at low gamma: demonstrates the
//!    collapse documented in DESIGN.md §4 / EXPERIMENTS.md.
//! 2. **mask-scope** — per-layer top-k (Alg. 4's layer loop) vs one global
//!    top-k over all maskable parameters.
//! 3. **decay-family** — exponential (Eq. 3) vs linear vs step annealing at
//!    matched total communication budget.
//! 4. **partition** — IID (paper) vs pathological non-IID shards
//!    (McMahan et al.), both under dynamic+selective.
//! 5. **downlink-delta** — delta-encoded broadcasts across the lossless
//!    (`auto`) and lossy (`auto-q8`, `auto-q4`) wire encodings, recording
//!    each run's worst per-round reconstruction error. The server already
//!    asserts the error stays within the encoding's quantizer half-step
//!    every round; this study is the data that makes flipping the
//!    `downlink_delta` default an evidence-backed decision (ROADMAP).

use crate::config::experiment::ExperimentConfig;
use crate::data::partition::Scheme;
use crate::figures::common::FigureCtx;
use crate::fl::masking::{MaskEngine, MaskPolicy, MaskScope, MaskTarget};
use crate::fl::sampling::SamplingSchedule;
use crate::metrics::csv::{fmt, Table};
use crate::transport::codec::Encoding;
use crate::util::error::Result;

pub fn run(ctx: &FigureCtx) -> Result<()> {
    let pool = ctx.pool("lenet", 6)?;
    let mut summary = Table::new(&["study", "variant", "test_accuracy", "uplink_units"]);

    let mut base = ExperimentConfig::defaults("lenet")?;
    base.clients = 10;
    base.rounds = if ctx.quick { 5 } else { 10 };
    base.eval_every = base.rounds;
    let base = ctx.apply(base);

    // 1. mask target at gamma = 0.2
    for (variant, target) in [("delta (default)", MaskTarget::Delta), ("weights (Alg.4 literal)", MaskTarget::Weights)] {
        let mut cfg = base.clone();
        cfg.label = format!("ablate-target-{variant}");
        cfg.masking = MaskPolicy::selective(0.2);
        cfg.mask_target = target;
        let out = ctx.run_config(cfg, &pool)?;
        summary.push(vec![
            "mask-target".into(),
            variant.into(),
            fmt(out.recorder.final_accuracy()),
            fmt(out.ledger.uplink_units),
        ]);
    }

    // 2. mask scope at gamma = 0.2
    for (variant, scope) in [("per-layer (Alg.4)", MaskScope::PerLayer), ("global", MaskScope::Global)] {
        let mut cfg = base.clone();
        cfg.label = format!("ablate-scope-{variant}");
        cfg.masking = MaskPolicy::Selective {
            gamma: 0.2,
            engine: MaskEngine::Rust,
            scope,
        };
        let out = ctx.run_config(cfg, &pool)?;
        summary.push(vec![
            "mask-scope".into(),
            variant.into(),
            fmt(out.recorder.final_accuracy()),
            fmt(out.ledger.uplink_units),
        ]);
    }

    // 3. decay family, budget-matched-ish (all land near the same total
    //    units over the horizon; exact totals reported alongside)
    let r = base.rounds;
    let schedules: [(&str, SamplingSchedule); 3] = [
        ("exponential (Eq.3)", SamplingSchedule::DynamicExp { c0: 1.0, beta: 0.2 }),
        ("linear", SamplingSchedule::DynamicLinear { c0: 1.0, slope: 1.0 / (1.5 * r as f64) }),
        ("step x0.5/3", SamplingSchedule::DynamicStep { c0: 1.0, every: 3, factor: 0.5 }),
    ];
    for (variant, sched) in schedules {
        let mut cfg = base.clone();
        cfg.label = format!("ablate-decay-{variant}");
        cfg.sampling = sched;
        cfg.min_clients = 2;
        let out = ctx.run_config(cfg, &pool)?;
        summary.push(vec![
            "decay-family".into(),
            variant.into(),
            fmt(out.recorder.final_accuracy()),
            fmt(out.ledger.uplink_units),
        ]);
    }

    // 4. partition scheme under dynamic+selective
    for (variant, scheme) in [("iid (paper)", Scheme::Iid), ("noniid-2shards", Scheme::NonIidShards { shards_per_client: 2 })] {
        let mut cfg = base.clone();
        cfg.label = format!("ablate-partition-{variant}");
        cfg.partition = scheme;
        cfg.sampling = SamplingSchedule::DynamicExp { c0: 1.0, beta: 0.1 };
        cfg.min_clients = 2;
        cfg.masking = MaskPolicy::selective(0.3);
        let out = ctx.run_config(cfg, &pool)?;
        summary.push(vec![
            "partition".into(),
            variant.into(),
            fmt(out.recorder.final_accuracy()),
            fmt(out.ledger.uplink_units),
        ]);
    }

    // 5. downlink-delta fidelity across wire encodings. A masked cohort
    //    leaves most broadcast-delta coordinates untouched, so the delta
    //    ships sparse; lossy value codes trade downlink bytes for a
    //    bounded reconstruction error the rounds record.
    for enc in [Encoding::Auto, Encoding::AutoQ8, Encoding::AutoQ4] {
        let mut cfg = base.clone();
        cfg.label = format!("ablate-downlink-{}", enc.as_str());
        cfg.masking = MaskPolicy::selective(0.3);
        cfg.downlink_delta = true;
        cfg.encoding = enc;
        let out = ctx.run_config(cfg, &pool)?;
        let max_err = out
            .recorder
            .rounds
            .iter()
            .map(|r| r.downlink_recon_err)
            .fold(0.0f64, f64::max);
        // The per-round half-step assertion lives in the server; this
        // cross-checks the aggregate claim the study exists to document.
        assert!(max_err.is_finite(), "reconstruction error must be finite");
        if enc == Encoding::Auto {
            assert!(
                max_err < 1e-4,
                "lossless delta downlink drifted beyond f32 rounding: {max_err}"
            );
        }
        summary.push(vec![
            "downlink-delta".into(),
            format!("{} (max recon err {:.3e})", enc.as_str(), max_err),
            fmt(out.recorder.final_accuracy()),
            fmt(out.ledger.downlink_units),
        ]);
    }

    println!("# ablations (MNIST/LeNet, {} rounds)", base.rounds);
    println!("# downlink-delta rows report downlink units; others uplink units");
    ctx.emit(&summary)
}
