//! Paper-figure harness: one driver per table/figure in the evaluation
//! section (§5), each regenerating the same series the paper plots.
//!
//! | id     | paper content                                             |
//! |--------|-----------------------------------------------------------|
//! | table1 | dataset statistics                                        |
//! | fig3   | MNIST static vs dynamic sampling: accuracy + cost         |
//! | fig4   | MNIST random vs selective masking, gamma sweep            |
//! | fig5   | MNIST combined dynamic sampling x masking                 |
//! | fig6   | CIFAR VGG random vs selective masking, gamma sweep        |
//! | fig7   | CIFAR decay-coefficient sweep x masking rates             |
//! | fig8   | WikiText GRU static vs dynamic x masking (perplexity)     |
//! | fig9   | WikiText GRU random vs selective masking (perplexity)     |
//!
//! Defaults are CPU-scaled (fewer clients/rounds than the paper's 100);
//! `--clients/--rounds/--paper-scale` restore paper geometry. Every driver
//! prints its series and writes CSV when `--out` is given.

pub mod ablations;
pub mod common;
pub mod fig3;
pub mod fig4;
pub mod fig5;
pub mod fig6;
pub mod fig7;
pub mod fig8;
pub mod fig9;
pub mod table1;

use crate::util::cli::Args;
use crate::util::error::{Error, Result};

/// All figure ids, in paper order.
pub const ALL: &[&str] = &[
    "table1", "fig3", "fig4", "fig5", "fig6", "fig7", "fig8", "fig9", "ablations",
];

/// Dispatch a figure driver by id.
pub fn run(id: &str, args: &Args) -> Result<()> {
    let ctx = common::FigureCtx::from_args(args)?;
    match id {
        "table1" => table1::run(&ctx),
        "fig3" => fig3::run(&ctx),
        "fig4" => fig4::run(&ctx),
        "fig5" => fig5::run(&ctx),
        "fig6" => fig6::run(&ctx),
        "fig7" => fig7::run(&ctx),
        "fig8" => fig8::run(&ctx),
        "fig9" => fig9::run(&ctx),
        "ablations" => ablations::run(&ctx),
        other => Err(Error::invalid(format!(
            "unknown figure '{other}'; available: {}",
            ALL.join(", ")
        ))),
    }
}
