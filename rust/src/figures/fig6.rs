//! Fig. 6: random vs selective masking on CIFAR-10/VGG.
//!
//! Paper setup: static sampling C = 1.0, 100 rounds, gamma swept 0.1..0.9
//! on the large conv model. Expected shape (§5.2.4): selective wins for
//! gamma in 0.1..0.6; the two converge at high gamma.
//!
//! CPU-scaled default: 8 clients, 10 rounds, VGG-mini (DESIGN.md §2).

use crate::config::experiment::ExperimentConfig;
use crate::figures::common::FigureCtx;
use crate::fl::masking::MaskPolicy;
use crate::fl::sampling::SamplingSchedule;
use crate::metrics::csv::{fmt, Table};
use crate::util::error::Result;

pub fn run(ctx: &FigureCtx) -> Result<()> {
    let gammas: Vec<f32> = if ctx.quick {
        vec![0.1, 0.5, 0.9]
    } else {
        vec![0.1, 0.3, 0.5, 0.7, 0.9]
    };
    let pool = ctx.pool("vggmini", 6)?;
    let mut summary = Table::new(&["policy", "gamma", "test_accuracy", "uplink_units", "uplink_bytes"]);

    let mut base = ExperimentConfig::defaults("vggmini")?;
    base.clients = 6;
    base.rounds = if ctx.quick { 4 } else { 6 };
    base.sampling = SamplingSchedule::Static { c0: 1.0 };
    base.eval_every = base.rounds;
    let base = ctx.apply(base);

    for &gamma in &gammas {
        for policy in [MaskPolicy::random(gamma), MaskPolicy::selective(gamma)] {
            let mut cfg = base.clone();
            cfg.masking = policy;
            cfg.label = format!("fig6-{}", policy.label());
            let out = ctx.run_config(cfg, &pool)?;
            summary.push(vec![
                match policy {
                    MaskPolicy::Random { .. } => "random".into(),
                    _ => "selective".into(),
                },
                fmt(gamma as f64),
                fmt(out.recorder.final_accuracy()),
                fmt(out.ledger.uplink_units),
                out.ledger.uplink_bytes.to_string(),
            ]);
            eprintln!("{}", out.recorder.summary());
        }
    }
    println!("# fig6: random vs selective masking accuracy by gamma (CIFAR/VGG)");
    ctx.emit(&summary)
}
