//! Fig. 9: random vs selective masking on WikiText/GRU (perplexity).
//!
//! Expected shape (§5.3): selective is better at larger masking rates;
//! random surprisingly wins at low gamma (the paper attributes this to a
//! regularization effect of randomness on the recurrent model).

use crate::config::experiment::ExperimentConfig;
use crate::figures::common::FigureCtx;
use crate::fl::masking::MaskPolicy;
use crate::fl::sampling::SamplingSchedule;
use crate::metrics::csv::{fmt, Table};
use crate::util::error::Result;

pub fn run(ctx: &FigureCtx) -> Result<()> {
    let gammas: Vec<f32> = if ctx.quick {
        vec![0.1, 0.5, 0.9]
    } else {
        vec![0.1, 0.3, 0.5, 0.7, 0.9]
    };
    let pool = ctx.pool("gru", 6)?;
    let mut summary = Table::new(&["policy", "gamma", "test_perplexity", "uplink_units"]);

    let mut base = ExperimentConfig::defaults("gru")?;
    base.clients = 10;
    base.rounds = if ctx.quick { 5 } else { 10 };
    base.sampling = SamplingSchedule::Static { c0: 0.5 };
    base.eval_every = base.rounds;
    let base = ctx.apply(base);

    for &gamma in &gammas {
        for policy in [MaskPolicy::random(gamma), MaskPolicy::selective(gamma)] {
            let mut cfg = base.clone();
            cfg.masking = policy;
            cfg.label = format!("fig9-{}", policy.label());
            let out = ctx.run_config(cfg, &pool)?;
            summary.push(vec![
                match policy {
                    MaskPolicy::Random { .. } => "random".into(),
                    _ => "selective".into(),
                },
                fmt(gamma as f64),
                fmt(out.recorder.final_perplexity()),
                fmt(out.ledger.uplink_units),
            ]);
            eprintln!("{}", out.recorder.summary());
        }
    }
    println!("# fig9: random vs selective masking (WikiText/GRU, perplexity)");
    ctx.emit(&summary)
}
