//! Fig. 5: combined dynamic sampling + masking on MNIST/LeNet.
//!
//! Paper setup: initial sampling rates C in {0.3, 0.5, 0.7, 1.0}, decay
//! beta in {0.01, 0.1}, random vs selective masking, 50 rounds. Expected
//! shape (§5.2.3): selective beats random in nearly every cell (paper's
//! exception: C = 1.0 with beta = 0.01).

use crate::config::experiment::ExperimentConfig;
use crate::figures::common::FigureCtx;
use crate::fl::masking::MaskPolicy;
use crate::fl::sampling::SamplingSchedule;
use crate::metrics::csv::{fmt, Table};
use crate::util::error::Result;

pub fn run(ctx: &FigureCtx) -> Result<()> {
    let c0s: Vec<f64> = if ctx.quick { vec![0.5, 1.0] } else { vec![0.3, 0.5, 0.7, 1.0] };
    let betas = [0.01, 0.1];
    let gamma = 0.5f32;
    let pool = ctx.pool("lenet", 6)?;
    let mut summary = Table::new(&[
        "beta",
        "c0",
        "policy",
        "gamma",
        "test_accuracy",
        "uplink_units",
    ]);

    let mut base = ExperimentConfig::defaults("lenet")?;
    base.rounds = if ctx.quick { 10 } else { 25 };
    base.eval_every = base.rounds;
    let base = ctx.apply(base);

    for &beta in &betas {
        for &c0 in &c0s {
            for policy in [MaskPolicy::random(gamma), MaskPolicy::selective(gamma)] {
                let mut cfg = base.clone();
                cfg.sampling = SamplingSchedule::DynamicExp { c0, beta };
                cfg.min_clients = 2;
                cfg.masking = policy;
                cfg.label = format!("fig5-b{beta}-c{c0}-{}", policy.label());
                let out = ctx.run_config(cfg, &pool)?;
                summary.push(vec![
                    fmt(beta),
                    fmt(c0),
                    match policy {
                        MaskPolicy::Random { .. } => "random".into(),
                        _ => "selective".into(),
                    },
                    fmt(gamma as f64),
                    fmt(out.recorder.final_accuracy()),
                    fmt(out.ledger.uplink_units),
                ]);
                eprintln!("{}", out.recorder.summary());
            }
        }
    }
    println!("# fig5: dynamic sampling x masking combined (MNIST)");
    ctx.emit(&summary)
}
