//! Table 1: dataset summary (type, #train, #test).
//!
//! Prints both the paper-scale statistics (what Table 1 reports) and the
//! actually-loaded statistics for this environment (synthetic unless the
//! real corpora are present under `data/`).

use crate::data::loader::{self, DatasetSpec};
use crate::figures::common::FigureCtx;
use crate::metrics::csv::Table;
use crate::util::error::Result;

pub fn run(ctx: &FigureCtx) -> Result<()> {
    let mut t = Table::new(&["dataset", "type", "paper_train", "paper_test", "loaded_train", "loaded_test"]);
    for (name, kind) in [("mnist", "image"), ("cifar10", "image"), ("wikitext2", "token")] {
        let paper = DatasetSpec::named(name, ctx.seed)?.paper_scale();
        let mut spec = DatasetSpec::named(name, ctx.seed)?;
        if ctx.paper_scale {
            spec = spec.paper_scale();
        }
        let ds = loader::load(&spec, std::path::Path::new("data"))?;
        t.push(vec![
            name.to_string(),
            kind.to_string(),
            paper.n_train.to_string(),
            paper.n_test.to_string(),
            ds.train_len().to_string(),
            ds.test_len().to_string(),
        ]);
    }
    ctx.emit(&t)
}
