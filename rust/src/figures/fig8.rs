//! Fig. 8: static vs dynamic sampling with masked updating on WikiText/GRU.
//!
//! Paper setup: 50 rounds, masking rates swept, perplexity after training;
//! dynamic sampling (beta in {0.1, 0.5}) vs static. Expected shape
//! (§5.3): dynamic achieves lower perplexity in most masking-rate cells.

use crate::config::experiment::ExperimentConfig;
use crate::figures::common::FigureCtx;
use crate::fl::masking::MaskPolicy;
use crate::fl::sampling::SamplingSchedule;
use crate::metrics::csv::{fmt, Table};
use crate::util::error::Result;

pub fn run(ctx: &FigureCtx) -> Result<()> {
    let gammas: Vec<f32> = if ctx.quick {
        vec![0.5, 0.9]
    } else {
        vec![0.3, 0.5, 0.7, 0.9]
    };
    let schedules = [
        SamplingSchedule::Static { c0: 1.0 },
        SamplingSchedule::DynamicExp { c0: 1.0, beta: 0.1 },
        SamplingSchedule::DynamicExp { c0: 1.0, beta: 0.5 },
    ];
    let pool = ctx.pool("gru", 6)?;
    let mut summary = Table::new(&[
        "schedule",
        "gamma",
        "test_perplexity",
        "uplink_units",
    ]);

    let mut base = ExperimentConfig::defaults("gru")?;
    base.clients = 8;
    base.rounds = if ctx.quick { 5 } else { 10 };
    base.eval_every = base.rounds;
    let base = ctx.apply(base);

    for &gamma in &gammas {
        for sched in &schedules {
            let mut cfg = base.clone();
            cfg.sampling = sched.clone();
            cfg.min_clients = sched.default_min_clients();
            cfg.masking = MaskPolicy::selective(gamma);
            cfg.label = format!("fig8-{}-g{gamma}", sched.label());
            let out = ctx.run_config(cfg, &pool)?;
            summary.push(vec![
                sched.label(),
                fmt(gamma as f64),
                fmt(out.recorder.final_perplexity()),
                fmt(out.ledger.uplink_units),
            ]);
            eprintln!("{}", out.recorder.summary());
        }
    }
    println!("# fig8: static vs dynamic sampling with masking (WikiText/GRU, perplexity)");
    ctx.emit(&summary)
}
