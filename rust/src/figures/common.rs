//! Shared figure-driver machinery: CLI context, sweep runner, CSV merging.

use std::path::PathBuf;
use std::sync::Arc;

use crate::config::experiment::ExperimentConfig;
use crate::fl::server::{Server, ServerOutcome};
use crate::metrics::csv::Table;
use crate::runtime::manifest::Manifest;
use crate::runtime::pool::EnginePool;
use crate::transport::codec::Encoding;
use crate::transport::link::TransportKind;
use crate::util::cli::{Args, OptSpec};
use crate::util::error::Result;

/// Options shared by every figure driver.
pub const FIGURE_OPTS: &[OptSpec] = &[
    OptSpec::value("out", "CSV output path (also printed to stdout)"),
    OptSpec::value("rounds", "override communication rounds"),
    OptSpec::value("clients", "override registered client count M"),
    OptSpec::value("seed", "experiment seed (default 42)"),
    OptSpec::value("workers", "engine pool width"),
    OptSpec::value("artifacts", "artifacts directory (default ./artifacts)"),
    OptSpec::value("transport", "upload wire: inproc|tcp|uds (default inproc)"),
    OptSpec::value(
        "encoding",
        "wire encoding: dense|sparse|sparse-delta|auto|auto-q8|auto-q4 (default auto)",
    ),
    OptSpec::flag(
        "downlink-delta",
        "ship every sweep's broadcast as an encoded delta over the downlink wire",
    ),
    OptSpec::flag("paper-scale", "paper-size datasets (60k MNIST etc.)"),
    OptSpec::flag("quick", "coarser sweeps for a fast smoke run"),
];

/// Parsed figure context.
pub struct FigureCtx {
    pub manifest: Manifest,
    pub out: Option<PathBuf>,
    pub rounds: Option<usize>,
    pub clients: Option<usize>,
    pub seed: u64,
    pub workers: Option<usize>,
    /// Upload transport override (`--transport tcp` reruns a whole sweep
    /// over real sockets; results are bitwise identical by construction).
    pub transport: Option<TransportKind>,
    /// Wire-encoding override (`--encoding sparse-delta` reruns a sweep
    /// under the entropy-coded wire; `auto-q4` adds 4-bit value loss).
    pub encoding: Option<Encoding>,
    /// Delta-downlink override (`--downlink-delta` reruns a whole sweep
    /// with the broadcast shipped as an encoded delta over the wire —
    /// the per-round `downlink_recon_err` column is the fidelity
    /// evidence).
    pub downlink_delta: bool,
    pub paper_scale: bool,
    pub quick: bool,
}

impl FigureCtx {
    pub fn from_args(args: &Args) -> Result<FigureCtx> {
        let artifacts = args.get("artifacts").unwrap_or("artifacts");
        Ok(FigureCtx {
            manifest: Manifest::load(artifacts)?,
            out: args.get("out").map(PathBuf::from),
            rounds: args.get("rounds").map(|s| s.parse()).transpose().map_err(|_| {
                crate::Error::invalid("--rounds must be an integer")
            })?,
            clients: args
                .get("clients")
                .map(|s| s.parse())
                .transpose()
                .map_err(|_| crate::Error::invalid("--clients must be an integer"))?,
            seed: args.get_or("seed", 42u64)?,
            workers: args
                .get("workers")
                .map(|s| s.parse())
                .transpose()
                .map_err(|_| crate::Error::invalid("--workers must be an integer"))?,
            transport: args.get("transport").map(TransportKind::parse).transpose()?,
            encoding: args.get("encoding").map(Encoding::parse).transpose()?,
            downlink_delta: args.has_flag("downlink-delta"),
            paper_scale: args.has_flag("paper-scale"),
            quick: args.has_flag("quick"),
        })
    }

    /// Apply the context overrides to a config.
    pub fn apply(&self, mut cfg: ExperimentConfig) -> ExperimentConfig {
        if let Some(r) = self.rounds {
            cfg.rounds = r;
        }
        if let Some(m) = self.clients {
            cfg.clients = m;
        }
        if let Some(w) = self.workers {
            cfg.workers = w;
        }
        if let Some(tr) = self.transport {
            cfg.transport = tr;
        }
        if let Some(enc) = self.encoding {
            cfg.encoding = enc;
        }
        if self.downlink_delta {
            cfg.downlink_delta = true;
        }
        cfg.seed = self.seed;
        if self.paper_scale {
            let spec = crate::data::loader::DatasetSpec::for_model(&cfg.model, cfg.seed)
                .expect("model known")
                .paper_scale();
            cfg.n_train = spec.n_train;
            cfg.n_test = spec.n_test;
        }
        cfg
    }

    /// Build a pool for `model` sized for this context.
    pub fn pool(&self, model: &str, workers: usize) -> Result<Arc<EnginePool>> {
        Ok(Arc::new(EnginePool::new(
            &self.manifest,
            &[model],
            self.workers.unwrap_or(workers),
        )?))
    }

    /// Run one configured experiment on a shared pool.
    pub fn run_config(
        &self,
        cfg: ExperimentConfig,
        pool: &Arc<EnginePool>,
    ) -> Result<ServerOutcome> {
        log::info!("running {}", cfg.label);
        Server::with_pool(cfg, &self.manifest, Arc::clone(pool))?.run()
    }

    /// Emit a finished table: print to stdout and write CSV if requested.
    pub fn emit(&self, table: &Table) -> Result<()> {
        table.print();
        if let Some(path) = &self.out {
            table.write(path)?;
            eprintln!("wrote {}", path.display());
        }
        Ok(())
    }
}

/// Append every round row of an outcome into a merged per-round table.
pub fn append_rounds(table: &mut Table, outcome: &ServerOutcome) {
    let t = outcome.recorder.table();
    // Table has no row iterator by design; rebuild from the recorder.
    let _ = t;
    for r in &outcome.recorder.rounds {
        table.push(vec![
            outcome.recorder.label.clone(),
            r.round.to_string(),
            crate::metrics::csv::fmt(r.sample_rate),
            r.clients.to_string(),
            crate::metrics::csv::fmt(r.train_loss),
            crate::metrics::csv::fmt(r.test_loss),
            crate::metrics::csv::fmt(r.test_accuracy),
            crate::metrics::csv::fmt(r.test_perplexity),
            crate::metrics::csv::fmt(r.uplink_units),
            r.uplink_bytes.to_string(),
            r.downlink_bytes.to_string(),
            crate::metrics::csv::fmt(r.downlink_recon_err),
            crate::metrics::csv::fmt(r.virtual_time_s),
            r.faults.events.len().to_string(),
        ]);
    }
}

/// The standard per-round merged header.
pub fn rounds_header() -> Table {
    Table::new(&[
        "label",
        "round",
        "sample_rate",
        "clients",
        "train_loss",
        "test_loss",
        "test_accuracy",
        "test_perplexity",
        "uplink_units",
        "uplink_bytes",
        "downlink_bytes",
        "downlink_recon_err",
        "virtual_time_s",
        "faults",
    ])
}
