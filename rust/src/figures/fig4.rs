//! Fig. 4: random vs selective masking on MNIST/LeNet.
//!
//! Paper setup: static sampling C = 0.1, 10 rounds, lr 0.01->our default,
//! masking rate gamma swept 0.1..0.9. Expected shape (§5.2.2): comparable
//! accuracy at high gamma; random masking collapses at gamma <= 0.2 while
//! selective stays usable.

use crate::config::experiment::ExperimentConfig;
use crate::figures::common::FigureCtx;
use crate::fl::masking::MaskPolicy;
use crate::fl::sampling::SamplingSchedule;
use crate::metrics::csv::{fmt, Table};
use crate::util::error::Result;

pub fn run(ctx: &FigureCtx) -> Result<()> {
    let gammas: Vec<f32> = if ctx.quick {
        vec![0.1, 0.5, 0.9]
    } else {
        vec![0.1, 0.2, 0.3, 0.4, 0.5, 0.6, 0.7, 0.8, 0.9]
    };
    let pool = ctx.pool("lenet", 6)?;
    let mut summary = Table::new(&["policy", "gamma", "test_accuracy", "uplink_units", "uplink_bytes"]);

    let mut base = ExperimentConfig::defaults("lenet")?;
    base.rounds = 10;
    base.sampling = SamplingSchedule::Static { c0: 0.1 };
    base.min_clients = 2; // 0.1 * 20 = 2 clients/round
    base.eval_every = base.rounds; // final accuracy only
    let base = ctx.apply(base);

    for &gamma in &gammas {
        for policy in [MaskPolicy::random(gamma), MaskPolicy::selective(gamma)] {
            let mut cfg = base.clone();
            cfg.masking = policy;
            cfg.label = format!("fig4-{}", policy.label());
            let out = ctx.run_config(cfg, &pool)?;
            summary.push(vec![
                match policy {
                    MaskPolicy::Random { .. } => "random".into(),
                    _ => "selective".into(),
                },
                fmt(gamma as f64),
                fmt(out.recorder.final_accuracy()),
                fmt(out.ledger.uplink_units),
                out.ledger.uplink_bytes.to_string(),
            ]);
            eprintln!("{}", out.recorder.summary());
        }
    }
    println!("# fig4: random vs selective masking accuracy by gamma (MNIST)");
    ctx.emit(&summary)
}
