//! Fig. 3: static vs dynamic sampling on MNIST/LeNet.
//!
//! Paper setup: 100% initial sampling; dynamic decay beta in {0.01, 0.1};
//! reports (a) test accuracy after 10/50/100 rounds and (b) cumulative
//! transport cost. CPU-scaled default: 20 clients, 30 rounds with
//! checkpoints at 20%/50%/100% of the horizon; `--rounds 100 --clients 100`
//! restores paper geometry.
//!
//! Expected shape (paper §5.2.1): dynamic(0.01) tracks or beats static at
//! short horizons and saves modest cost; dynamic(0.1) trades accuracy at
//! longer horizons for large savings; static always costs 100%.

use crate::config::experiment::ExperimentConfig;
use crate::figures::common::{append_rounds, rounds_header, FigureCtx};
use crate::fl::sampling::SamplingSchedule;
use crate::metrics::csv::{fmt, Table};
use crate::util::error::Result;

pub fn run(ctx: &FigureCtx) -> Result<()> {
    let schedules = [
        SamplingSchedule::Static { c0: 1.0 },
        SamplingSchedule::DynamicExp { c0: 1.0, beta: 0.01 },
        SamplingSchedule::DynamicExp { c0: 1.0, beta: 0.1 },
    ];
    let pool = ctx.pool("lenet", 6)?;
    let mut rounds_table = rounds_header();
    let mut summary = Table::new(&[
        "schedule",
        "checkpoint_round",
        "test_accuracy",
        "cum_uplink_units",
        "cost_vs_static_pct",
    ]);

    let mut base = ExperimentConfig::defaults("lenet")?;
    base.rounds = 30;
    base.eval_every = 1;
    let base = ctx.apply(base);
    let checkpoints = [
        (base.rounds / 3).max(1),
        (base.rounds * 2 / 3).max(1),
        base.rounds,
    ];
    let static_units_at = |r: usize, m: usize| (r * m) as f64;

    for sched in schedules {
        let mut cfg = base.clone();
        cfg.label = sched.label();
        cfg.sampling = sched.clone();
        cfg.min_clients = sched.default_min_clients();
        let out = ctx.run_config(cfg, &pool)?;
        append_rounds(&mut rounds_table, &out);
        for &cp in &checkpoints {
            let rec = &out.recorder.rounds[cp - 1];
            summary.push(vec![
                sched.label(),
                cp.to_string(),
                fmt(rec.test_accuracy),
                fmt(rec.uplink_units),
                fmt(100.0 * rec.uplink_units / static_units_at(cp, base.clients)),
            ]);
        }
        eprintln!("{}", out.recorder.summary());
    }

    println!("# fig3a/fig3b summary (accuracy + cost at checkpoints)");
    ctx.emit(&summary)?;
    if let Some(out) = &ctx.out {
        let rounds_path = out.with_extension("rounds.csv");
        rounds_table.write(&rounds_path)?;
        eprintln!("wrote {}", rounds_path.display());
    }
    Ok(())
}
