//! Fig. 7: effect of the decay coefficient under masking on CIFAR-10/VGG.
//!
//! Paper setup: dynamic sampling with beta swept on a log axis, masking
//! rates gamma in {0.3, 0.5, 0.7, 0.9}, random vs selective. Expected
//! shape (§5.2.4): selective >= random at low/mid gamma; performance
//! fluctuates then drops by beta = 0.5 (most communication-efficient).

use crate::config::experiment::ExperimentConfig;
use crate::figures::common::FigureCtx;
use crate::fl::masking::MaskPolicy;
use crate::fl::sampling::SamplingSchedule;
use crate::metrics::csv::{fmt, Table};
use crate::util::error::Result;

pub fn run(ctx: &FigureCtx) -> Result<()> {
    let betas: Vec<f64> = if ctx.quick {
        vec![0.01, 0.1, 0.5]
    } else {
        vec![0.01, 0.05, 0.1, 0.5]
    };
    let gammas: Vec<f32> = if ctx.quick { vec![0.3, 0.9] } else { vec![0.3, 0.5, 0.7, 0.9] };
    let pool = ctx.pool("vggmini", 6)?;
    let mut summary = Table::new(&[
        "gamma",
        "beta",
        "policy",
        "test_accuracy",
        "uplink_units",
    ]);

    let mut base = ExperimentConfig::defaults("vggmini")?;
    base.clients = 6;
    base.rounds = if ctx.quick { 4 } else { 6 };
    base.eval_every = base.rounds;
    let base = ctx.apply(base);

    for &gamma in &gammas {
        for &beta in &betas {
            for policy in [MaskPolicy::random(gamma), MaskPolicy::selective(gamma)] {
                let mut cfg = base.clone();
                cfg.sampling = SamplingSchedule::DynamicExp { c0: 1.0, beta };
                cfg.min_clients = 2;
                cfg.masking = policy;
                cfg.label = format!("fig7-g{gamma}-b{beta}-{}", policy.label());
                let out = ctx.run_config(cfg, &pool)?;
                summary.push(vec![
                    fmt(gamma as f64),
                    fmt(beta),
                    match policy {
                        MaskPolicy::Random { .. } => "random".into(),
                        _ => "selective".into(),
                    },
                    fmt(out.recorder.final_accuracy()),
                    fmt(out.ledger.uplink_units),
                ]);
                eprintln!("{}", out.recorder.summary());
            }
        }
    }
    println!("# fig7: decay coefficient x masking rate (CIFAR/VGG, log-x beta)");
    ctx.emit(&summary)
}
