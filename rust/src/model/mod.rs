//! Model registry: static facts about the model zoo that are not derivable
//! from the artifact manifest (paper pairing, tuned defaults), plus pretty
//! inspection of a loaded manifest.

use crate::runtime::manifest::{Manifest, ModelManifest};
use crate::util::error::{Error, Result};

/// Static registry entry for one model.
#[derive(Debug, Clone, Copy)]
pub struct ModelInfo {
    pub name: &'static str,
    /// The paper's dataset for this learner.
    pub dataset: &'static str,
    /// The paper's model this reproduces.
    pub paper_model: &'static str,
    /// Tuned default learning rate on the synthetic corpora.
    pub default_lr: f32,
    /// Headline metric.
    pub metric: Metric,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Metric {
    Accuracy,
    Perplexity,
}

/// All models, in paper order.
pub const REGISTRY: &[ModelInfo] = &[
    ModelInfo {
        name: "lenet",
        dataset: "mnist",
        paper_model: "LeNet [18]",
        default_lr: 0.05,
        metric: Metric::Accuracy,
    },
    ModelInfo {
        name: "vggmini",
        dataset: "cifar10",
        paper_model: "VGG-16 [31] (CPU-scaled)",
        default_lr: 0.05,
        metric: Metric::Accuracy,
    },
    ModelInfo {
        name: "gru",
        dataset: "wikitext2",
        paper_model: "GRU [5] tied-embedding LM",
        default_lr: 0.5,
        metric: Metric::Perplexity,
    },
];

/// Look up a registry entry.
pub fn info(name: &str) -> Result<&'static ModelInfo> {
    REGISTRY
        .iter()
        .find(|m| m.name == name)
        .ok_or_else(|| Error::invalid(format!("unknown model '{name}'")))
}

/// Render a human-readable description of one model's manifest entry.
pub fn describe(mm: &ModelManifest) -> String {
    let mut out = format!(
        "{}: task={} P={} batch={} nb_train={} nb_eval={} maskable={} ({:.1}%)\n",
        mm.name,
        mm.task,
        mm.p,
        mm.batch,
        mm.nb_train,
        mm.nb_eval,
        mm.maskable_params(),
        100.0 * mm.maskable_params() as f64 / mm.p as f64,
    );
    for l in &mm.layers {
        out.push_str(&format!(
            "  {:<10} {:?} offset={} size={} masked={}\n",
            l.name, l.shape, l.offset, l.size, l.masked
        ));
    }
    out
}

/// Render the whole manifest.
pub fn describe_manifest(manifest: &Manifest) -> String {
    let mut out = String::new();
    for mm in manifest.models.values() {
        out.push_str(&describe(mm));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_covers_paper_models() {
        assert_eq!(REGISTRY.len(), 3);
        assert_eq!(info("lenet").unwrap().dataset, "mnist");
        assert_eq!(info("gru").unwrap().metric, Metric::Perplexity);
        assert!(info("bert").is_err());
    }

    #[test]
    fn describe_lists_layers() {
        use std::collections::BTreeMap;
        let mm = ModelManifest {
            name: "toy".into(),
            p: 6,
            task: "image".into(),
            batch: 2,
            nb_train: 1,
            nb_eval: 1,
            x_elem_shape: vec![3],
            x_dtype: "f32".into(),
            y_elem_shape: vec![],
            layers: vec![crate::runtime::manifest::LayerInfo {
                name: "w".into(),
                shape: vec![2, 3],
                offset: 0,
                size: 6,
                masked: true,
            }],
            artifacts: BTreeMap::new(),
            meta: BTreeMap::new(),
        };
        let text = describe(&mm);
        assert!(text.contains("toy"));
        assert!(text.contains("P=6"));
        assert!(text.contains("masked=true"));
    }
}
