//! `fedlint` — run the project-invariant static-analysis pass over this
//! repo's own sources and docs.
//!
//! ```text
//! fedlint [--root <repo-root>] [--deny-all] [--json <path>]
//! ```
//!
//! Prints one `file:line: [rule] message` per finding. With `--deny-all`
//! (what CI runs) any finding is exit code 1; without it findings are
//! advisory and the exit code stays 0. `--json` additionally writes a
//! machine-readable summary. Rules, rationale, and the allowlist syntax
//! are documented in `rust/docs/LINTS.md`.

use std::path::{Path, PathBuf};
use std::process::ExitCode;

use fedmask::lint::{self, SourceTree};
use fedmask::util::json::Json;

fn usage() -> &'static str {
    concat!(
        "fedlint — project-invariant static analysis (see rust/docs/LINTS.md)\n\n",
        "usage: fedlint [--root <repo-root>] [--deny-all] [--json <path>]\n\n",
        "  --root <path>   repo root to scan (default: auto-detect from cwd)\n",
        "  --deny-all      exit 1 on any finding (the CI gate)\n",
        "  --json <path>   write a machine-readable summary\n\n",
        "suppress a finding with a line comment on (or above) the line:\n",
        "  // fed", "lint: allow(<rule>) -- <reason>\n",
    )
}

/// The repo root is the directory holding `rust/src`: the cwd when run
/// from the checkout root, its parent when run from `rust/` (where
/// `cargo run --bin fedlint` puts you).
fn detect_root() -> Option<PathBuf> {
    let cwd = std::env::current_dir().ok()?;
    if cwd.join("rust/src").is_dir() {
        return Some(cwd);
    }
    if cwd.join("src").is_dir() {
        if let Some(parent) = cwd.parent() {
            if parent.join("rust/src").is_dir() {
                return Some(parent.to_path_buf());
            }
        }
    }
    None
}

fn main() -> ExitCode {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let mut root: Option<PathBuf> = None;
    let mut deny_all = false;
    let mut json_path: Option<PathBuf> = None;
    let mut it = argv.iter();
    while let Some(arg) = it.next() {
        match arg.as_str() {
            "--root" => match it.next() {
                Some(p) => root = Some(PathBuf::from(p)),
                None => {
                    eprintln!("--root needs a path\n\n{}", usage());
                    return ExitCode::from(2);
                }
            },
            "--deny-all" => deny_all = true,
            "--json" => match it.next() {
                Some(p) => json_path = Some(PathBuf::from(p)),
                None => {
                    eprintln!("--json needs a path\n\n{}", usage());
                    return ExitCode::from(2);
                }
            },
            "--help" | "-h" => {
                print!("{}", usage());
                return ExitCode::SUCCESS;
            }
            other => {
                eprintln!("unknown argument '{other}'\n\n{}", usage());
                return ExitCode::from(2);
            }
        }
    }
    let Some(root) = root.or_else(detect_root) else {
        eprintln!(
            "fedlint: cannot find a repo root (no rust/src here or one level up); pass --root"
        );
        return ExitCode::from(2);
    };

    let tree = match SourceTree::load(&root) {
        Ok(t) => t,
        Err(e) => {
            eprintln!("fedlint: {e}");
            return ExitCode::from(2);
        }
    };
    let diags = lint::run(&tree);

    for d in &diags {
        println!("{}:{}: [{}] {}", d.file, d.line, d.rule, d.message);
    }
    println!(
        "fedlint: {} file(s) scanned, {} finding(s)",
        tree.files.len(),
        diags.len()
    );

    if let Some(path) = &json_path {
        if let Err(e) = write_summary(path, &tree, &diags) {
            eprintln!("fedlint: writing {}: {e}", path.display());
            return ExitCode::from(2);
        }
    }

    if deny_all && !diags.is_empty() {
        ExitCode::FAILURE
    } else {
        ExitCode::SUCCESS
    }
}

fn write_summary(
    path: &Path,
    tree: &SourceTree,
    diags: &[lint::Diagnostic],
) -> std::io::Result<()> {
    let mut rules: Vec<(&str, Json)> = Vec::new();
    for rule in lint::RULES {
        let n = diags.iter().filter(|d| d.rule == *rule).count();
        rules.push((rule, Json::num(n as f64)));
    }
    let doc = Json::obj(vec![
        ("schema", Json::num(1.0)),
        ("files_scanned", Json::num(tree.files.len() as f64)),
        ("findings", Json::num(diags.len() as f64)),
        ("rules", Json::obj(rules)),
        (
            "diagnostics",
            Json::Arr(
                diags
                    .iter()
                    .map(|d| {
                        Json::obj(vec![
                            ("file", Json::str(&d.file)),
                            ("line", Json::num(d.line as f64)),
                            ("rule", Json::str(d.rule)),
                            ("message", Json::str(&d.message)),
                        ])
                    })
                    .collect(),
            ),
        ),
    ]);
    std::fs::write(path, doc.to_string() + "\n")
}
