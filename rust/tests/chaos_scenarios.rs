//! Chaos harness acceptance: the recovery contract under seeded fault
//! injection, pinned end-to-end through the real `RoundDriver` phases.
//!
//! The contract (see `docs/CHAOS.md`):
//!
//! * **Determinism** — two runs with the same experiment seed and the
//!   same chaos seed produce byte-identical `RoundRecord`s (fault log
//!   included) and bitwise-equal aggregates, across transports,
//!   encodings and mask targets.
//! * **Survivor equivalence** — a chaotic round's aggregate is
//!   bitwise-equal to a clean run folded over exactly the clients whose
//!   uploads survived (delivered or duplicated), with duplicates folded
//!   once.
//! * **Typed rejection** — corrupt and Byzantine uploads die pre-fold;
//!   a round with no honest survivor fails with a typed transport error
//!   instead of hanging or folding garbage.
//! * **Billing** — every spawned upload is billed (the radio spent the
//!   bytes whether or not the server could use them); duplicate frames
//!   bill bytes and messages but never model units.
//! * **Session reuse** — a downlink disconnect mid-broadcast skips that
//!   client's round; the same session carries its traffic next round.
//!
//! Everything here is engine-free (no PJRT artifacts needed). The
//! socket arm of the session-reuse test is gated on
//! `FEDMASK_SOCKET_TESTS=1` like the rest of the socket suite.

use std::sync::mpsc::channel;
use std::sync::Arc;
use std::time::Duration;

use fedmask::config::experiment::{AggregatorKind, ExperimentConfig, NetworkKind};
use fedmask::fl::aggregate::{make_aggregator, Contribution, SparseContribution};
use fedmask::fl::chaos::{DownlinkFate, FaultKind, FaultPlan, Scenario, UploadFate};
use fedmask::fl::client::receive_broadcast;
use fedmask::fl::driver::{JobMeta, RoundDriver};
use fedmask::fl::masking::MaskTarget;
use fedmask::metrics::recorder::RoundRecord;
use fedmask::runtime::manifest::LayerInfo;
use fedmask::sim::availability::AvailabilityModel;
use fedmask::transport::codec::{
    decode_update, encode_update, encode_update_cached, DecodedBody, Encoding, TAG_SPARSE_CACHED,
    TAG_SPARSE_DELTA,
};
use fedmask::transport::link::TransportKind;

// ---------------------------------------------------------------------
// Shared fixtures
// ---------------------------------------------------------------------

fn socket_arm_enabled() -> bool {
    match std::env::var("FEDMASK_SOCKET_TESTS") {
        Ok(v) if v == "1" || v == "true" => true,
        _ => {
            eprintln!("skipping socket arm (set FEDMASK_SOCKET_TESTS=1 to enable)");
            false
        }
    }
}

fn always_on(seed: u64) -> AvailabilityModel {
    AvailabilityModel::new(1.0, 0.0, seed)
}

fn one_layer(size: usize) -> Vec<LayerInfo> {
    vec![LayerInfo {
        name: "w".into(),
        shape: vec![size],
        offset: 0,
        size,
        masked: true,
    }]
}

fn initial_params(p: usize) -> Vec<f32> {
    (0..p).map(|j| (j as f32 * 0.37).sin()).collect()
}

/// Deterministic fake update derived from the broadcast the client
/// decoded off the wire — same shape as the socket suite's, so any
/// downlink discrepancy changes the aggregate.
fn fake_update(global: &[f32], client: usize) -> Vec<f32> {
    global
        .iter()
        .enumerate()
        .map(|(j, g)| {
            if j % 4 == client % 4 {
                g * 0.5 + (client as f32 + 1.0) * 0.125
            } else {
                0.0
            }
        })
        .collect()
}

/// The canonical upload a (client, round) pair produces from `global`.
fn canonical_payload(global: &[f32], client: usize, t: usize, enc: Encoding) -> Vec<u8> {
    let update = fake_update(global, client);
    encode_update(client as u32, t as u32, 10 + client as u32, &update, enc)
}

/// Fold encoded payloads into a finished aggregate — the clean-run
/// reference the chaotic driver runs are compared against bitwise (the
/// streaming fold is order-independent, so arrival order is irrelevant).
fn fold_payloads(
    payloads: &[Vec<u8>],
    target: MaskTarget,
    broadcast: &[f32],
    layers: &[LayerInfo],
) -> Vec<f32> {
    let mut agg = make_aggregator(AggregatorKind::FedAvg, target, broadcast, layers).unwrap();
    for bytes in payloads {
        let u = decode_update(bytes).unwrap();
        match &u.body {
            DecodedBody::Dense(v) => agg
                .fold(Contribution {
                    client: u.client as usize,
                    params: v,
                    n_samples: u.n_samples,
                })
                .unwrap(),
            DecodedBody::Sparse { indices, values } => agg
                .fold_sparse(SparseContribution {
                    client: u.client as usize,
                    p: u.p,
                    indices,
                    values,
                    n_samples: u.n_samples,
                })
                .unwrap(),
        }
    }
    agg.finish().unwrap()
}

/// Clean-run aggregate over exactly `survivors`, folding each once.
fn clean_fold(
    global: &[f32],
    survivors: &[usize],
    t: usize,
    enc: Encoding,
    target: MaskTarget,
    layers: &[LayerInfo],
) -> Vec<f32> {
    let payloads: Vec<Vec<u8>> =
        survivors.iter().map(|&c| canonical_payload(global, c, t, enc)).collect();
    fold_payloads(&payloads, target, global, layers)
}

/// Which clients' uploads survive round `t` under `plan`: downlink
/// delivered (so the job ran) and upload fate Deliver or Duplicate
/// (duplicates fold exactly once). Pure plan arithmetic — no transport.
fn surviving_clients(plan: &FaultPlan, t: usize, clients: usize) -> Vec<usize> {
    (0..clients)
        .filter(|&c| {
            plan.downlink_fate(t as u32, c as u32) == DownlinkFate::Deliver
                && matches!(
                    plan.upload_fate(t as u32, c as u32),
                    UploadFate::Deliver | UploadFate::Duplicate
                )
        })
        .collect()
}

// ---------------------------------------------------------------------
// The chaotic-round harness: real driver phases, fake clients on threads
// ---------------------------------------------------------------------

/// Everything a chaotic run produces that the contract pins.
#[derive(Debug, PartialEq)]
struct ChaosOutcome {
    records: Vec<RoundRecord>,
    aggregates: Vec<Vec<f32>>,
    /// Per round, the wire tag each spawned client *encoded* (before the
    /// chaos layer decided the upload's fate), sorted by client id. This
    /// is what pins the cache-recovery contract: a client whose previous
    /// upload was lost must fall back to a stateless full-index send
    /// (`TAG_SPARSE_DELTA`), never emit a desynced `TAG_SPARSE_CACHED`.
    tags: Vec<Vec<(usize, u8)>>,
}

/// Drive `rounds` full sample → broadcast → collect → finalize cycles
/// under whatever `cfg.chaos` injects, with fake clients on threads
/// pulling the broadcast off the downlink and uploading through the
/// (chaos-wrapped) sink. Jobs are spawned only where `wire.spawn` says
/// the client received the broadcast. Metric fields a real server would
/// fill from evaluation are pinned to 0.0 (not NaN — the records must
/// compare equal).
fn run_chaos_rounds(
    cfg: ExperimentConfig,
    rounds: usize,
    target: MaskTarget,
    p: usize,
) -> ChaosOutcome {
    let enc = cfg.encoding;
    let cfg = Arc::new(cfg);
    let mut driver = RoundDriver::new(Arc::clone(&cfg), p).unwrap();
    driver.set_upload_timeout(Duration::from_secs(30));
    let layers = one_layer(p);
    let mut records = Vec::new();
    let mut aggregates: Vec<Vec<f32>> = Vec::new();
    let mut tags: Vec<Vec<(usize, u8)>> = Vec::new();
    let mut params: Arc<Vec<f32>> = Arc::new(initial_params(p));

    for t in 1..=rounds {
        let cohort = driver.sample(&always_on(7), t);
        assert_eq!(cohort.selected.len(), cfg.clients, "static C=1 selects everyone");
        let wire = driver.broadcast(&params, &cohort).unwrap();
        let sink = driver.sink();
        let downlink = driver.downlink();
        let (tx, results) = channel::<(usize, fedmask::Result<JobMeta>)>();
        let (tag_tx, tag_rx) = channel::<(usize, u8)>();
        // spawn only downlink-reached clients; the drain indexes its metas
        // by dense job position, hence the re-enumeration to `j`
        let handles: Vec<_> = cohort
            .selected
            .iter()
            .enumerate()
            .filter(|&(i, _)| wire.spawn[i])
            .enumerate()
            .map(|(j, (i, &c))| {
                let sink = Arc::clone(&sink);
                let downlink = Arc::clone(&downlink);
                let reference = wire.references[i].clone();
                // same Arc the server will decode with, handed over at
                // broadcast time — None forces a stateless full-index send
                let cache = wire.index_caches[i].clone();
                let tx = tx.clone();
                let tag_tx = tag_tx.clone();
                std::thread::spawn(move || {
                    let global = receive_broadcast(
                        downlink.as_ref(),
                        c as u32,
                        t as u32,
                        reference.as_deref().map(Vec::as_slice),
                        Duration::from_secs(30),
                    )
                    .unwrap();
                    let update = fake_update(&global, c);
                    let nnz = update.iter().filter(|v| **v != 0.0).count();
                    let payload = encode_update_cached(
                        c as u32,
                        t as u32,
                        10 + c as u32,
                        &update,
                        enc,
                        cache.as_deref(),
                    );
                    let bytes = payload.len();
                    tag_tx.send((c, payload[3])).unwrap();
                    // the chaos sink decides this upload's fate; Ok either way
                    sink.send(payload).unwrap();
                    tx.send((j, Ok((0.25, nnz, bytes)))).unwrap();
                })
            })
            .collect();
        drop(tx);
        drop(tag_tx);
        let mut agg =
            make_aggregator(AggregatorKind::FedAvg, target, &wire.params, &layers).unwrap();
        let collected = driver.collect(&cohort, agg.as_mut(), &results).unwrap();
        for h in handles {
            h.join().unwrap();
        }
        let mut round_tags: Vec<(usize, u8)> = tag_rx.iter().collect();
        round_tags.sort_unstable();
        tags.push(round_tags);
        let cost = driver.finalize(&collected);
        let aggregate = agg.finish().unwrap();
        let ledger = driver.ledger();
        records.push(RoundRecord {
            round: t,
            sample_rate: cohort.rate,
            clients: cohort.selected.len(),
            train_loss: cost.loss_sum / collected.metas.len().max(1) as f64,
            test_loss: 0.0,
            test_accuracy: 0.0,
            test_perplexity: 0.0,
            uplink_units: ledger.uplink_units,
            uplink_bytes: ledger.uplink_bytes,
            downlink_bytes: ledger.downlink_bytes,
            downlink_recon_err: wire.recon_err,
            virtual_time_s: 0.0,
            faults: driver.take_fault_log(t),
        });
        params = Arc::new(aggregate.clone());
        aggregates.push(aggregate);
    }
    ChaosOutcome { records, aggregates, tags }
}

fn base_cfg(clients: usize, enc: Encoding) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::defaults("lenet").unwrap();
    cfg.clients = clients;
    cfg.encoding = enc;
    cfg
}

// ---------------------------------------------------------------------
// Seed searches: pure plan arithmetic, no transport. The fate of every
// (round, client) is a pure function of the chaos seed, so a seed with
// the coverage a test needs can be found without running anything.
// ---------------------------------------------------------------------

fn soup_plan(seed: u64) -> FaultPlan {
    FaultPlan {
        seed,
        drop_prob: 0.25,
        dup_prob: 0.25,
        byzantine_clients: vec![2],
        reorder: true,
        ..FaultPlan::default()
    }
}

/// A chaos-soup seed where both rounds exercise the whole menu: among
/// the honest clients, at least one drop, at least one duplicate, and
/// at least one survivor (implied by the duplicate) per round.
fn find_soup_seed(clients: usize) -> u64 {
    'seed: for seed in 0..10_000u64 {
        let plan = soup_plan(seed);
        for t in 1..=2u32 {
            let fates: Vec<UploadFate> = (0..clients as u32)
                .filter(|c| !plan.byzantine_clients.contains(c))
                .map(|c| plan.upload_fate(t, c))
                .collect();
            let drops = fates.iter().filter(|f| matches!(f, UploadFate::Drop)).count();
            let dups = fates.iter().filter(|f| matches!(f, UploadFate::Duplicate)).count();
            if drops == 0 || dups == 0 {
                continue 'seed;
            }
        }
        return seed;
    }
    panic!("no chaos-soup seed with full fault coverage in 10k candidates");
}

/// A corrupt-plan seed where round 1 has at least one corrupted and at
/// least one cleanly delivered upload.
fn find_corrupt_seed(plan_of: impl Fn(u64) -> FaultPlan, clients: usize) -> u64 {
    for seed in 0..10_000u64 {
        let plan = plan_of(seed);
        let fates: Vec<UploadFate> =
            (0..clients as u32).map(|c| plan.upload_fate(1, c)).collect();
        let corrupt = fates.iter().filter(|f| matches!(f, UploadFate::Corrupt)).count();
        let clean = fates.iter().filter(|f| matches!(f, UploadFate::Deliver)).count();
        if corrupt >= 1 && clean >= 1 {
            return seed;
        }
    }
    panic!("no corrupt seed in 10k candidates");
}

fn flaky_plan(seed: u64) -> FaultPlan {
    FaultPlan { seed, disconnect_downlink_prob: 0.4, ..FaultPlan::default() }
}

/// A flaky-downlink seed where round 1 disconnects some but not all of
/// the cohort, and at least one round-1 casualty is back (downlink
/// delivered) in round 2 — the session-reuse witness.
fn find_flaky_seed(clients: usize) -> u64 {
    for seed in 0..10_000u64 {
        let plan = flaky_plan(seed);
        let down1: Vec<u32> = (0..clients as u32)
            .filter(|&c| plan.downlink_fate(1, c) == DownlinkFate::Disconnect)
            .collect();
        if down1.is_empty() || down1.len() == clients {
            continue;
        }
        if down1.iter().any(|&c| plan.downlink_fate(2, c) == DownlinkFate::Deliver) {
            return seed;
        }
    }
    panic!("no flaky-downlink seed in 10k candidates");
}

// ---------------------------------------------------------------------
// Acceptance: chaos-soup determinism + survivor equivalence
// ---------------------------------------------------------------------

/// The PR's acceptance bar. One plan mixing drops, duplicates, seeded
/// reordering and a Byzantine peer, run **twice** per configuration:
/// the two runs' `RoundRecord`s (fault log included) are byte-identical
/// and the aggregates bitwise-equal — across the in-process and
/// simulated transports, both mask targets, and two encodings. And the
/// chaotic aggregate equals a clean run folded over exactly the
/// surviving cohort, round-chained.
#[test]
fn chaos_soup_is_deterministic_and_folds_like_a_clean_run_on_survivors() {
    let p = 24;
    let clients = 6;
    let seed = find_soup_seed(clients);
    let plan = soup_plan(seed);
    let layers = one_layer(p);

    for network in [NetworkKind::Ideal, NetworkKind::Simulated] {
        for enc in [Encoding::Auto, Encoding::AutoQ8] {
            for target in [MaskTarget::Delta, MaskTarget::Weights] {
                let ctx = format!("{network:?}/{enc:?}/{target:?} seed {seed}");
                let cfg = || {
                    let mut cfg = base_cfg(clients, enc);
                    cfg.network = network;
                    cfg.chaos = Some(plan.clone());
                    cfg
                };
                let a = run_chaos_rounds(cfg(), 2, target, p);
                let b = run_chaos_rounds(cfg(), 2, target, p);
                assert_eq!(a.records, b.records, "{ctx}: records diverged between reruns");
                assert_eq!(a.aggregates, b.aggregates, "{ctx}: aggregates diverged");

                // survivor equivalence, chained: round 2 folds from the
                // round-1 chaotic aggregate
                let mut global = initial_params(p);
                for t in 1..=2usize {
                    let survivors = surviving_clients(&plan, t, clients);
                    assert!(!survivors.is_empty(), "{ctx}: seed search guarantees a survivor");
                    assert!(!survivors.contains(&2), "{ctx}: the Byzantine peer never folds");
                    let expected = clean_fold(&global, &survivors, t, enc, target, &layers);
                    assert_eq!(
                        a.aggregates[t - 1],
                        expected,
                        "{ctx}: round-{t} aggregate != clean fold over survivors {survivors:?}"
                    );
                    global = expected;
                }

                // the fault log names every injection the plan predicted
                for (t, rec) in a.records.iter().enumerate() {
                    let t = t + 1;
                    let kinds: Vec<FaultKind> =
                        rec.faults.events.iter().map(|e| e.kind).collect();
                    assert!(kinds.contains(&FaultKind::DropUpload), "{ctx}: round {t} drop");
                    assert!(
                        kinds.contains(&FaultKind::DuplicateUpload),
                        "{ctx}: round {t} duplicate"
                    );
                    assert!(
                        rec.faults
                            .events
                            .iter()
                            .any(|e| e.kind == FaultKind::ByzantineUpload && e.client == 2),
                        "{ctx}: round {t} Byzantine injection logged"
                    );
                }
            }
        }
    }
}

// ---------------------------------------------------------------------
// Wire v3: chaos-soup with cross-round index caching enabled
// ---------------------------------------------------------------------

/// The same chaos-soup, with `SparseCached` switched on. Three pins:
/// reruns stay byte-identical (the cache lifecycle is part of the
/// deterministic state machine); survivor aggregates are bitwise-equal
/// to a clean **stateless** fold (the cached arm is lossless, so
/// statefulness must never leak into values); and the recovery rule —
/// round 1 is all full-index sends, and in round 2 exactly the clients
/// whose round-1 upload folded hold a cache and send the set-delta,
/// while a dropped, corrupted or Byzantine round-1 upload forces that
/// session back to a stateless full-index send, never a desynced delta.
#[test]
fn chaos_soup_with_sparse_cached_recovers_to_full_index_sends() {
    // p large enough that the zero-churn cached body beats the stateless
    // delta (the 12-byte epoch/count overhead must undercut the nnz
    // index bytes), so a live cache demonstrably flips the tag
    let p = 96;
    let clients = 6;
    let seed = find_soup_seed(clients);
    let plan = soup_plan(seed);
    let layers = one_layer(p);

    for network in [NetworkKind::Ideal, NetworkKind::Simulated] {
        for target in [MaskTarget::Delta, MaskTarget::Weights] {
            let ctx = format!("{network:?}/{target:?} seed {seed}");
            let cfg = || {
                let mut cfg = base_cfg(clients, Encoding::SparseCached);
                cfg.network = network;
                cfg.chaos = Some(plan.clone());
                cfg
            };
            let a = run_chaos_rounds(cfg(), 2, target, p);
            let b = run_chaos_rounds(cfg(), 2, target, p);
            assert_eq!(a, b, "{ctx}: outcomes (records/aggregates/tags) diverged");

            // survivor equivalence against a clean *stateless* fold,
            // round-chained — the reference never sees a cache
            let mut global = initial_params(p);
            for t in 1..=2usize {
                let survivors = surviving_clients(&plan, t, clients);
                let expected =
                    clean_fold(&global, &survivors, t, Encoding::SparseDelta, target, &layers);
                assert_eq!(
                    a.aggregates[t - 1],
                    expected,
                    "{ctx}: round-{t} cached aggregate != clean stateless fold over {survivors:?}"
                );
                global = expected;
            }

            // round 1: nobody holds a cache yet — every upload is a
            // stateless full-index send
            for &(c, tag) in &a.tags[0] {
                assert_eq!(tag, TAG_SPARSE_DELTA, "{ctx}: client {c} sent a delta with no cache");
            }
            // round 2: exactly the round-1 survivors hold a live cache
            // (the fake masks don't churn, so their set-delta is empty and
            // strictly cheaper); everyone else was invalidated
            let survivors1 = surviving_clients(&plan, 1, clients);
            for &(c, tag) in &a.tags[1] {
                let want = if survivors1.contains(&c) {
                    TAG_SPARSE_CACHED
                } else {
                    TAG_SPARSE_DELTA
                };
                assert_eq!(
                    tag,
                    want,
                    "{ctx}: client {c} round-2 tag (round-1 survivor: {})",
                    survivors1.contains(&c)
                );
            }
            // the seed search guarantees both witnesses exist: at least
            // one cached send and at least one forced full send
            assert!(
                a.tags[1].iter().any(|&(_, t)| t == TAG_SPARSE_CACHED),
                "{ctx}: no client exercised the cached arm"
            );
            assert!(
                a.tags[1].iter().any(|&(_, t)| t == TAG_SPARSE_DELTA),
                "{ctx}: no dropped client fell back to a full send"
            );
        }
    }
}

// ---------------------------------------------------------------------
// Billing: duplicates fold once, bill twice
// ---------------------------------------------------------------------

/// Every upload duplicated: the aggregate equals the clean single-copy
/// fold (duplicates fold exactly once), model units are billed once per
/// client, but the byte ledger carries both copies.
#[test]
fn duplicate_uploads_fold_once_and_bill_bytes_twice() {
    let p = 24;
    let clients = 4;
    let enc = Encoding::Auto;
    let mut cfg = base_cfg(clients, enc);
    cfg.chaos = Some(FaultPlan { seed: 0xd0b1e, dup_prob: 1.0, ..FaultPlan::default() });
    let layers = one_layer(p);

    let out = run_chaos_rounds(cfg, 1, MaskTarget::Delta, p);

    let global = initial_params(p);
    let all: Vec<usize> = (0..clients).collect();
    let expected = clean_fold(&global, &all, 1, enc, MaskTarget::Delta, &layers);
    assert_eq!(out.aggregates[0], expected, "duplicates must fold exactly once");

    // byte accounting: each payload billed once as the job's upload and
    // once as redundant duplicate traffic; units accrue only once
    let payloads: Vec<Vec<u8>> =
        all.iter().map(|&c| canonical_payload(&global, c, 1, enc)).collect();
    let once: u64 = payloads.iter().map(|p| p.len() as u64).sum();
    let rec = &out.records[0];
    assert_eq!(rec.uplink_bytes, 2 * once, "duplicate frames must be billed as bytes");
    let expected_units: f64 = all
        .iter()
        .map(|&c| {
            let nnz = fake_update(&global, c).iter().filter(|v| **v != 0.0).count();
            nnz as f64 / p as f64
        })
        .sum();
    assert!(
        (rec.uplink_units - expected_units).abs() < 1e-12,
        "duplicate frames must never accrue model units: {} vs {expected_units}",
        rec.uplink_units
    );

    // one DuplicateUpload event per client, in canonical order
    let dup_clients: Vec<u32> = rec
        .faults
        .events
        .iter()
        .filter(|e| e.kind == FaultKind::DuplicateUpload)
        .map(|e| e.client)
        .collect();
    assert_eq!(dup_clients, vec![0, 1, 2, 3]);
}

// ---------------------------------------------------------------------
// Typed failure: no honest survivor
// ---------------------------------------------------------------------

/// When the plan leaves nothing to aggregate, `collect` fails fast with
/// a typed transport error — before draining, so the round can't hang
/// waiting for uploads that will never arrive.
#[test]
fn a_round_with_no_honest_survivor_fails_with_a_typed_error() {
    let p = 16;
    let mut cfg = base_cfg(2, Encoding::Auto);
    cfg.chaos = Some(FaultPlan { seed: 1, drop_prob: 1.0, ..FaultPlan::default() });
    let cfg = Arc::new(cfg);
    let mut driver = RoundDriver::new(Arc::clone(&cfg), p).unwrap();
    let cohort = driver.sample(&always_on(7), 1);
    let params: Arc<Vec<f32>> = Arc::new(initial_params(p));
    let wire = driver.broadcast(&params, &cohort).unwrap();
    let layers = one_layer(p);
    let mut agg =
        make_aggregator(AggregatorKind::FedAvg, MaskTarget::Delta, &wire.params, &layers).unwrap();
    let (_tx, results) = channel::<(usize, fedmask::Result<JobMeta>)>();
    let err = driver.collect(&cohort, agg.as_mut(), &results).unwrap_err();
    assert!(matches!(err, fedmask::Error::Transport(_)), "{err}");
    assert!(err.to_string().contains("no honest upload"), "{err}");
}

// ---------------------------------------------------------------------
// Pre-fold rejection: Byzantine and corrupt uploads
// ---------------------------------------------------------------------

/// Three of four clients are Byzantine every round: their well-formed,
/// wrong-width frames die at the pre-fold width check and the round
/// completes on the lone honest upload.
#[test]
fn byzantine_uploads_are_rejected_pre_fold_leaving_the_honest_survivor() {
    let p = 24;
    let enc = Encoding::Auto;
    let mut cfg = base_cfg(4, enc);
    cfg.chaos = Some(FaultPlan {
        seed: 0xb42,
        byzantine_clients: vec![1, 2, 3],
        ..FaultPlan::default()
    });
    let layers = one_layer(p);

    let out = run_chaos_rounds(cfg, 1, MaskTarget::Delta, p);

    let global = initial_params(p);
    let expected = clean_fold(&global, &[0], 1, enc, MaskTarget::Delta, &layers);
    assert_eq!(out.aggregates[0], expected, "only the honest client may fold");

    let byz: Vec<u32> = out.records[0]
        .faults
        .events
        .iter()
        .filter(|e| e.kind == FaultKind::ByzantineUpload)
        .map(|e| e.client)
        .collect();
    assert_eq!(byz, vec![1, 2, 3], "every forged upload is logged");
}

/// Corrupted payloads (truncated or bit-flipped in flight) are rejected
/// before any body decode reaches the fold; the surviving uploads
/// aggregate exactly as a clean run over the survivors would.
#[test]
fn corrupt_payloads_are_rejected_pre_fold_and_logged() {
    let p = 24;
    let clients = 6;
    let enc = Encoding::Auto;
    let plan_of = |seed| FaultPlan { seed, corrupt_prob: 0.5, ..FaultPlan::default() };
    let seed = find_corrupt_seed(plan_of, clients);
    let plan = plan_of(seed);
    let mut cfg = base_cfg(clients, enc);
    cfg.chaos = Some(plan.clone());
    let layers = one_layer(p);

    let out = run_chaos_rounds(cfg, 1, MaskTarget::Delta, p);

    let global = initial_params(p);
    let survivors = surviving_clients(&plan, 1, clients);
    let expected = clean_fold(&global, &survivors, 1, enc, MaskTarget::Delta, &layers);
    assert_eq!(
        out.aggregates[0], expected,
        "seed {seed}: mangled payloads must not contaminate the fold"
    );

    let predicted: Vec<u32> = (0..clients as u32)
        .filter(|&c| plan.upload_fate(1, c) == UploadFate::Corrupt)
        .collect();
    let logged: Vec<u32> = out.records[0]
        .faults
        .events
        .iter()
        .filter(|e| e.kind == FaultKind::CorruptUpload)
        .map(|e| e.client)
        .collect();
    assert_eq!(logged, predicted, "seed {seed}: every corruption is logged");
}

// ---------------------------------------------------------------------
// Session reuse across a downlink disconnect
// ---------------------------------------------------------------------

/// A client whose downlink dies mid-broadcast skips the round (no job,
/// no upload, no fold) — and its session carries traffic again the next
/// round. The socket arm pins the part that matters operationally: the
/// persistent authenticated TCP session survives the swallowed
/// broadcast and produces an outcome byte-identical to in-process.
#[test]
fn downlink_disconnect_skips_the_round_and_the_session_is_reusable() {
    let p = 24;
    let clients = 4;
    let enc = Encoding::Auto;
    let seed = find_flaky_seed(clients);
    let plan = flaky_plan(seed);
    let layers = one_layer(p);
    let cfg = |transport: TransportKind| {
        let mut cfg = base_cfg(clients, enc);
        cfg.transport = transport;
        cfg.chaos = Some(plan.clone());
        cfg
    };

    let out = run_chaos_rounds(cfg(TransportKind::InProcess), 2, MaskTarget::Delta, p);

    // round-chained survivor equivalence: round 1 folds the reached
    // cohort, round 2 folds from round 1's aggregate — with at least one
    // round-1 casualty back in (the seed search guarantees it)
    let down1 = surviving_clients(&plan, 1, clients);
    let down2 = surviving_clients(&plan, 2, clients);
    let casualties: Vec<usize> = (0..clients).filter(|c| !down1.contains(c)).collect();
    assert!(!casualties.is_empty() && down1.len() < clients, "seed {seed}: search contract");
    assert!(
        casualties.iter().any(|c| down2.contains(c)),
        "seed {seed}: a round-1 casualty must return in round 2"
    );
    let r1 = clean_fold(&initial_params(p), &down1, 1, enc, MaskTarget::Delta, &layers);
    assert_eq!(out.aggregates[0], r1, "seed {seed}: round 1 folds the reached cohort");
    let r2 = clean_fold(&r1, &down2, 2, enc, MaskTarget::Delta, &layers);
    assert_eq!(out.aggregates[1], r2, "seed {seed}: the returned client folds in round 2");

    // the disconnects are logged, and only in round 1's record
    let logged: Vec<u32> = out.records[0]
        .faults
        .events
        .iter()
        .filter(|e| e.kind == FaultKind::DisconnectDownlink)
        .map(|e| e.client)
        .collect();
    let expected: Vec<u32> = casualties.iter().map(|&c| c as u32).collect();
    assert_eq!(logged, expected, "seed {seed}");

    // socket arm: same plan over persistent TCP sessions, byte-identical
    if socket_arm_enabled() {
        let tcp = run_chaos_rounds(cfg(TransportKind::Tcp), 2, MaskTarget::Delta, p);
        assert_eq!(tcp, out, "seed {seed}: TCP sessions must match in-process bitwise");
    }
}

// ---------------------------------------------------------------------
// Scenario layer: named registry drives the same machinery
// ---------------------------------------------------------------------

/// The `scrambled-arrivals` scenario (simulated network + seeded
/// reordering) perturbs only arrival order: the aggregate is the clean
/// full-cohort fold, and two runs are byte-identical.
#[test]
fn scrambled_arrivals_scenario_reorders_without_moving_the_aggregate() {
    let p = 24;
    let clients = 6;
    let enc = Encoding::Auto;
    let scenario = Scenario::named("scrambled-arrivals").unwrap();
    let cfg = || {
        let mut cfg = base_cfg(clients, enc);
        scenario.apply(&mut cfg);
        cfg
    };
    assert_eq!(cfg().network, NetworkKind::Simulated, "the scenario simulates the network");
    assert!(cfg().chaos.as_ref().is_some_and(|c| c.reorder), "the scenario reorders");

    let a = run_chaos_rounds(cfg(), 1, MaskTarget::Delta, p);
    let b = run_chaos_rounds(cfg(), 1, MaskTarget::Delta, p);
    assert_eq!(a, b, "scenario runs must be reproducible");

    let layers = one_layer(p);
    let all: Vec<usize> = (0..clients).collect();
    let expected =
        clean_fold(&initial_params(p), &all, 1, enc, MaskTarget::Delta, &layers);
    assert_eq!(a.aggregates[0], expected, "reordering must never change the fold");
    assert!(a.records[0].faults.events.is_empty(), "reordering alone injects no faults");
}
