//! Cross-module property tests (seeded randomized cases via util::prop).
//!
//! These pin the coordinator invariants the paper's results depend on:
//! sampling monotonicity and cost accounting, masking exactness, codec
//! faithfulness, aggregation conservation, and — when artifacts are
//! present — agreement between the L1 Pallas mask kernel and the exact
//! rust oracle.

use fedmask::config::experiment::AggregatorKind;
use fedmask::fl::aggregate::{
    make_aggregator, weighted_mean, Aggregator, Contribution, SparseContribution, StreamingFedAvg,
};
use fedmask::fl::masking::{self, MaskScope, MaskScratch, MaskTarget};
use fedmask::fl::pipeline::mask_stream_selective;
use fedmask::fl::sampling::SamplingSchedule;
use fedmask::runtime::manifest::{LayerInfo, Manifest};
use fedmask::transport::codec::{
    decode_update, encode_masked, encode_update, encode_update_cached_with, DecodedBody,
    EncodeScratch, Encoding, MaskedStream,
};
use fedmask::transport::session::IndexCache;
use fedmask::transport::cost::eq6_cost;
use fedmask::util::prop::{check, Gen};

fn layer(offset: usize, size: usize, masked: bool) -> LayerInfo {
    LayerInfo {
        name: format!("l{offset}"),
        shape: vec![size],
        offset,
        size,
        masked,
    }
}

#[test]
fn prop_eq6_equals_roundwise_simulation() {
    check("eq6 closed form vs simulation", 100, |g| {
        let c0 = g.f64_in(0.1, 1.0);
        let beta = g.f64_in(0.0, 0.5);
        let gamma = g.f64_in(0.05, 1.0);
        let rounds = g.usize_in(1, 80);
        let closed = eq6_cost(c0, beta, gamma, rounds);
        let mut acc = 0.0;
        for t in 1..=rounds {
            acc += gamma * c0 / (beta * t as f64).exp();
        }
        let sim = acc / rounds as f64;
        assert!((closed - sim).abs() < 1e-10);
    });
}

#[test]
fn prop_dynamic_sampling_total_cost_below_static() {
    check("dynamic cheaper than static", 100, |g| {
        let c0 = g.f64_in(0.1, 1.0);
        let beta = g.f64_in(0.01, 0.5);
        let rounds = g.usize_in(2, 100);
        let dynamic = SamplingSchedule::DynamicExp { c0, beta };
        let dyn_cost: f64 = (1..=rounds).map(|t| dynamic.rate(t)).sum();
        let static_cost = c0 * rounds as f64;
        assert!(dyn_cost < static_cost);
    });
}

#[test]
fn prop_masked_vector_roundtrips_and_is_cheaper() {
    check("masked wire roundtrip + saving", 60, |g| {
        let n = g.usize_in(64, 4000);
        let gamma = g.f32_in(0.05, 0.45);
        let wn = g.normal_vec(n);
        let wo = g.normal_vec(n);
        let layers = vec![layer(0, n, true)];
        let masked = masking::selective_mask_rust(&wn, &wo, gamma, &layers, MaskScope::PerLayer);
        let dense_bytes = encode_update(0, 0, 1, &wn, Encoding::Dense).len();
        let sparse = encode_update(0, 0, 1, &masked, Encoding::Auto);
        assert!(sparse.len() < dense_bytes, "gamma<0.5 must ship sparse");
        let back = decode_update(&sparse).unwrap();
        assert_eq!(back.to_dense(), masked);
    });
}

#[test]
fn prop_codec_roundtrips_all_encodings_including_degenerate_sizes() {
    check("codec roundtrip incl. empty/single payloads", 120, |g| {
        // bias toward the degenerate sizes the wire must survive
        let p = match g.usize_in(0, 9) {
            0 => 0,
            1 => 1,
            _ => g.usize_in(2, 1500),
        };
        let density = g.f32_in(0.0, 1.0);
        let params: Vec<f32> = (0..p)
            .map(|_| {
                if g.f32_in(0.0, 1.0) < density {
                    g.f32_in(-2.0, 2.0)
                } else {
                    0.0
                }
            })
            .collect();
        for enc in [
            Encoding::Dense,
            Encoding::Sparse,
            Encoding::SparseDelta,
            Encoding::Auto,
        ] {
            let u = decode_update(&encode_update(9, 4, 77, &params, enc)).unwrap();
            assert_eq!(u.client, 9);
            assert_eq!(u.round, 4);
            assert_eq!(u.n_samples, 77);
            assert_eq!(u.to_dense(), params, "enc {enc:?} p {p} seed {:#x}", g.seed);
        }
        // q8/q4 are lossy: lengths and headers exact, values within half a
        // quantization step of a [-2, 2] value range (16 levels for q4)
        for (enc, levels) in [(Encoding::AutoQ8, 255.0f32), (Encoding::AutoQ4, 15.0)] {
            let u = decode_update(&encode_update(9, 4, 77, &params, enc)).unwrap();
            assert_eq!(u.p, p);
            let dense = u.to_dense();
            let half_step = 0.5 * 4.0 / levels + 1e-6;
            for (a, b) in params.iter().zip(&dense) {
                assert!(
                    (a - b).abs() <= half_step,
                    "{enc:?} p {p} err {} seed {:#x}",
                    (a - b).abs(),
                    g.seed
                );
            }
        }
    });
}

#[test]
fn prop_streamed_fold_matches_barrier_in_any_arrival_order() {
    check("streamed == barrier, any order", 60, |g| {
        let p = g.usize_in(1, 400);
        let k = g.usize_in(1, 12);
        let vecs: Vec<Vec<f32>> = (0..k).map(|_| g.normal_vec(p)).collect();
        let weights: Vec<u32> = (0..k).map(|_| g.usize_in(1, 1000) as u32).collect();
        let contribs: Vec<Contribution> = vecs
            .iter()
            .zip(&weights)
            .enumerate()
            .map(|(client, (v, &w))| Contribution {
                client,
                params: v,
                n_samples: w,
            })
            .collect();
        let barrier = weighted_mean(&contribs).unwrap();
        let mut order: Vec<usize> = (0..k).collect();
        let mut rng = fedmask::sim::rng::Rng::new(g.seed ^ 0xa11);
        rng.shuffle(&mut order);
        let mut agg = StreamingFedAvg::new(p);
        for &i in &order {
            agg.fold(contribs[i].clone()).unwrap();
        }
        let streamed = Box::new(agg).finish().unwrap();
        assert_eq!(streamed, barrier, "order {order:?} seed {:#x}", g.seed);
    });
}

/// Tentpole acceptance: for every encoding (incl. lossy q8) and both mask
/// targets, folding the wire bodies sparsely (O(nnz), no densification) is
/// **bitwise** identical to folding their densified forms — including
/// empty (p = 0) and all-zero payloads. Under `Delta` the aggregate must
/// also agree (to f32 noise) with the explicit reconstruct-then-average
/// reference the server used to compute per contribution.
#[test]
fn prop_sparse_fold_bitwise_equals_dense_fold_for_both_mask_targets() {
    check("sparse fold == dense fold, both targets", 80, |g| {
        let p = match g.usize_in(0, 9) {
            0 => 0,
            1 => 1,
            _ => g.usize_in(2, 600),
        };
        // two layers: the first masked, the second not (biases stay dense)
        let split = if p == 0 { 0 } else { g.usize_in(0, p) };
        let layers = vec![
            LayerInfo {
                name: "w".into(),
                shape: vec![split],
                offset: 0,
                size: split,
                masked: true,
            },
            LayerInfo {
                name: "b".into(),
                shape: vec![p - split],
                offset: split,
                size: p - split,
                masked: false,
            },
        ];
        let broadcast: Vec<f32> = (0..p).map(|_| g.f32_in(-1.0, 1.0)).collect();
        let k = g.usize_in(1, 6);
        let clients: Vec<(Vec<f32>, u32)> = (0..k)
            .map(|_| {
                // occasionally a fully-masked (all-zero) upload
                let density = match g.usize_in(0, 4) {
                    0 => 0.0,
                    _ => g.f32_in(0.05, 0.7),
                };
                let v: Vec<f32> = (0..p)
                    .map(|_| {
                        if g.f32_in(0.0, 1.0) < density {
                            g.f32_in(-1.5, 1.5)
                        } else {
                            0.0
                        }
                    })
                    .collect();
                (v, g.usize_in(1, 500) as u32)
            })
            .collect();
        for &enc in Encoding::ALL {
            for target in [MaskTarget::Weights, MaskTarget::Delta] {
                let mut make = || -> StreamingFedAvg {
                    match target {
                        MaskTarget::Weights => StreamingFedAvg::new(p),
                        MaskTarget::Delta => {
                            StreamingFedAvg::with_delta_baseline(&broadcast, &layers).unwrap()
                        }
                    }
                };
                let mut dense_agg = make();
                let mut sparse_agg = make();
                let mut recons: Vec<Vec<f32>> = Vec::new();
                for (i, (v, w)) in clients.iter().enumerate() {
                    let u = decode_update(&encode_update(i as u32, 1, *w, v, enc)).unwrap();
                    let dense = u.to_dense();
                    dense_agg
                        .fold(Contribution { client: i, params: &dense, n_samples: *w })
                        .unwrap();
                    match &u.body {
                        DecodedBody::Dense(d) => sparse_agg
                            .fold(Contribution { client: i, params: d, n_samples: *w })
                            .unwrap(),
                        DecodedBody::Sparse { indices, values } => sparse_agg
                            .fold_sparse(SparseContribution {
                                client: i,
                                p,
                                indices,
                                values,
                                n_samples: *w,
                            })
                            .unwrap(),
                    }
                    recons.push(match target {
                        MaskTarget::Weights => dense,
                        MaskTarget::Delta => {
                            masking::apply_delta_target(&dense, &broadcast, &layers)
                        }
                    });
                }
                let a = Box::new(dense_agg).finish().unwrap();
                let b = Box::new(sparse_agg).finish().unwrap();
                assert_eq!(a, b, "enc {enc:?} target {target:?} seed {:#x}", g.seed);
                // semantic reference: reconstruct densely per client, then
                // plain weighted mean (bit-identity is not expected here —
                // the baseline term rounds once, not per client)
                let contribs: Vec<Contribution> = recons
                    .iter()
                    .zip(&clients)
                    .enumerate()
                    .map(|(i, (r, (_, w)))| Contribution { client: i, params: r, n_samples: *w })
                    .collect();
                let reference = weighted_mean(&contribs).unwrap();
                for (x, y) in a.iter().zip(&reference) {
                    assert!(
                        (x - y).abs() <= 1e-5,
                        "enc {enc:?} target {target:?}: {x} vs reference {y} (seed {:#x})",
                        g.seed
                    );
                }
            }
        }
    });
}

/// Sharded-aggregation acceptance: partition a cohort's wire payloads over
/// S shard-local partial folds **in any way whatsoever** — including empty
/// shards and the degenerate single-shard partition — merge the partials
/// into the first shard's root in shard order, and the finished model is
/// **bitwise** identical to folding every payload into one flat
/// aggregator. Exercised for shard counts {1, 2, 8}, both mask targets,
/// and all six wire encodings (decode happens before the fold, so lossy
/// q8/q4 bodies must agree bitwise too — both sides fold the same decoded
/// values). This is the invariant `fl::tree::ShardedAggregator` relies on;
/// the fold arithmetic is integer fixed-point, so merge order and
/// partition shape must not matter.
#[test]
fn prop_sharded_merge_bitwise_equals_flat_fold_any_partition() {
    check("sharded merge == flat fold, any partition", 40, |g| {
        let p = match g.usize_in(0, 9) {
            0 => 0,
            1 => 1,
            _ => g.usize_in(2, 400),
        };
        let split = if p == 0 { 0 } else { g.usize_in(0, p) };
        let layers = vec![layer(0, split, true), {
            let mut b = layer(split, p - split, false);
            b.name = "b".into();
            b
        }];
        let broadcast: Vec<f32> = (0..p).map(|_| g.f32_in(-1.0, 1.0)).collect();
        let k = g.usize_in(1, 8);
        let clients: Vec<(Vec<f32>, u32)> = (0..k)
            .map(|_| {
                let density = g.f32_in(0.0, 0.7);
                let v: Vec<f32> = (0..p)
                    .map(|_| {
                        if g.f32_in(0.0, 1.0) < density {
                            g.f32_in(-1.5, 1.5)
                        } else {
                            0.0
                        }
                    })
                    .collect();
                (v, g.usize_in(1, 500) as u32)
            })
            .collect();
        for &enc in Encoding::ALL {
            for target in [MaskTarget::Weights, MaskTarget::Delta] {
                let make = || -> Box<dyn Aggregator> {
                    make_aggregator(AggregatorKind::FedAvg, target, &broadcast, &layers).unwrap()
                };
                // fold a decoded wire body into any aggregator
                let fold_into = |agg: &mut dyn Aggregator, i: usize| {
                    let (v, w) = &clients[i];
                    let u = decode_update(&encode_update(i as u32, 1, *w, v, enc)).unwrap();
                    match &u.body {
                        DecodedBody::Dense(d) => agg
                            .fold(Contribution { client: i, params: d, n_samples: *w })
                            .unwrap(),
                        DecodedBody::Sparse { indices, values } => agg
                            .fold_sparse(SparseContribution {
                                client: i,
                                p,
                                indices,
                                values,
                                n_samples: *w,
                            })
                            .unwrap(),
                    }
                };
                let mut flat = make();
                for i in 0..k {
                    fold_into(flat.as_mut(), i);
                }
                let reference = flat.finish().unwrap();
                for shards in [1usize, 2, 8] {
                    // arbitrary partition: each client lands on a random
                    // shard; with k <= 8 and 8 shards, empty shards are
                    // the common case, and shards == 1 is the flat fold
                    // routed through the merge path
                    let assign: Vec<usize> =
                        (0..k).map(|_| g.usize_in(0, shards - 1)).collect();
                    let mut partials: Vec<Box<dyn Aggregator>> =
                        (0..shards).map(|_| make()).collect();
                    for i in 0..k {
                        fold_into(partials[assign[i]].as_mut(), i);
                    }
                    let mut root = partials.remove(0);
                    for partial in partials {
                        root.merge(partial).unwrap();
                    }
                    let merged = root.finish().unwrap();
                    assert_eq!(
                        merged, reference,
                        "shards {shards} assign {assign:?} enc {enc:?} target \
                         {target:?} seed {:#x}",
                        g.seed
                    );
                }
            }
        }
    });
}

#[test]
fn prop_aggregation_conserves_weighted_sum() {
    check("aggregation conservation", 60, |g| {
        let p = g.usize_in(1, 500);
        let k = g.usize_in(1, 10);
        let vecs: Vec<Vec<f32>> = (0..k).map(|_| g.normal_vec(p)).collect();
        let weights: Vec<u32> = (0..k).map(|_| g.usize_in(1, 1000) as u32).collect();
        let contribs: Vec<Contribution> = vecs
            .iter()
            .zip(&weights)
            .enumerate()
            .map(|(client, (v, &w))| Contribution {
                client,
                params: v,
                n_samples: w,
            })
            .collect();
        let out = weighted_mean(&contribs).unwrap();
        let total: f64 = weights.iter().map(|&w| w as f64).sum();
        // check a few random coordinates against the direct formula
        for _ in 0..5.min(p) {
            let j = g.usize_in(0, p - 1);
            let want: f64 = vecs
                .iter()
                .zip(&weights)
                .map(|(v, &w)| v[j] as f64 * w as f64 / total)
                .sum();
            assert!((out[j] as f64 - want).abs() < 1e-5, "coord {j}");
        }
    });
}

#[test]
fn prop_selective_mask_idempotent() {
    check("masking idempotence", 40, |g| {
        let n = g.usize_in(16, 1000);
        let gamma = g.f32_in(0.1, 0.9);
        let wn = g.normal_vec(n);
        let wo = g.normal_vec(n);
        let layers = vec![layer(0, n, true)];
        let once = masking::selective_mask_rust(&wn, &wo, gamma, &layers, MaskScope::PerLayer);
        // masking the masked vector with the same reference keeps exactly
        // the survivors (their |delta| ranks only grow vs zeroed entries
        // whose delta is |wo|... not guaranteed; instead assert:
        // re-masking with gamma=1 is identity)
        let again = masking::selective_mask_rust(&once, &wo, 1.0, &layers, MaskScope::PerLayer);
        assert_eq!(once, again);
    });
}

/// Fused-pipeline acceptance: the single-pass mask→quantize→encode path
/// (`mask_stream_selective` + `encode_masked`) must be a drop-in for the
/// staged mask-then-encode path at the **byte** level. Checked for every
/// wire encoding, both mask scopes, index cache present and absent, and
/// the degenerate inputs the masker can face — empty model, all-zero
/// delta, and tie-heavy constant-|delta| vectors (which exercise the
/// shared tie budget). The stream's census sideband (nnz) must also match
/// the dense nonzero count. Both mask *targets* ship these same uplink
/// bytes (Delta reconstruction is server-side), so target equivalence is
/// checked at the fold: aggregating the fused frame under `Weights` and
/// `Delta` is bitwise identical to folding the staged dense mask.
#[test]
fn prop_fused_mask_encode_bitwise_equals_staged() {
    check("fused mask+encode == staged, all encodings", 40, |g| {
        // 1-3 consecutive layers, first always masked, zero-size allowed
        let nl = g.usize_in(1, 3);
        let mut layers = Vec::new();
        let mut off = 0usize;
        for i in 0..nl {
            let size = match g.usize_in(0, 5) {
                0 => 0,
                _ => g.usize_in(1, 250),
            };
            let mut l = layer(off, size, i == 0 || g.bool());
            l.name = format!("l{i}");
            layers.push(l);
            off += size;
        }
        let p = off;
        let wo = g.normal_vec(p);
        let wn: Vec<f32> = match g.usize_in(0, 3) {
            0 => wo.clone(),                            // all-zero delta
            1 => wo.iter().map(|v| v + 0.25).collect(), // tie-heavy
            _ => g.normal_vec(p),
        };
        let gamma = match g.usize_in(0, 4) {
            0 => 0.0,
            1 => 1.0,
            _ => g.f32_in(0.05, 0.95),
        };
        let cache = IndexCache::first((0..p as u32).filter(|_| g.bool()).collect());
        let mut mask_scratch = MaskScratch::default();
        let mut stream = MaskedStream::default();
        let mut scratch = EncodeScratch::default();
        let mut fused = Vec::new();
        for scope in [MaskScope::PerLayer, MaskScope::Global] {
            let masked = masking::selective_mask_rust(&wn, &wo, gamma, &layers, scope);
            mask_stream_selective(&wn, &wo, gamma, &layers, scope, &mut mask_scratch, &mut stream)
                .unwrap();
            assert_eq!(
                stream.nnz(),
                masked.iter().filter(|v| **v != 0.0).count(),
                "census nnz, scope {scope:?} seed {:#x}",
                g.seed
            );
            for &enc in Encoding::ALL {
                for cached in [None, Some(&cache)] {
                    let staged =
                        encode_update_cached_with(&mut scratch, 7, 3, 55, &masked, enc, cached);
                    encode_masked(&mut scratch, &mut fused, 7, 3, 55, &stream, enc, cached)
                        .unwrap();
                    assert_eq!(
                        fused, staged,
                        "enc {enc:?} scope {scope:?} cache {} gamma {gamma} p {p} seed {:#x}",
                        cached.is_some(),
                        g.seed
                    );
                }
            }
            for target in [MaskTarget::Weights, MaskTarget::Delta] {
                let mut make = || -> StreamingFedAvg {
                    match target {
                        MaskTarget::Weights => StreamingFedAvg::new(p),
                        MaskTarget::Delta => {
                            StreamingFedAvg::with_delta_baseline(&wo, &layers).unwrap()
                        }
                    }
                };
                let mut from_wire = make();
                let mut from_dense = make();
                encode_masked(&mut scratch, &mut fused, 7, 3, 55, &stream, Encoding::Auto, None)
                    .unwrap();
                let u = decode_update(&fused).unwrap();
                match &u.body {
                    DecodedBody::Dense(d) => from_wire
                        .fold(Contribution { client: 7, params: d, n_samples: 55 })
                        .unwrap(),
                    DecodedBody::Sparse { indices, values } => from_wire
                        .fold_sparse(SparseContribution {
                            client: 7,
                            p,
                            indices,
                            values,
                            n_samples: 55,
                        })
                        .unwrap(),
                }
                from_dense
                    .fold(Contribution { client: 7, params: &masked, n_samples: 55 })
                    .unwrap();
                assert_eq!(
                    Box::new(from_wire).finish().unwrap(),
                    Box::new(from_dense).finish().unwrap(),
                    "target {target:?} scope {scope:?} seed {:#x}",
                    g.seed
                );
            }
        }
    });
}

/// Encoder-only anchor for the fused path: loading a `MaskedStream` from
/// an arbitrary (unmasked) sparse vector via `from_dense` and encoding it
/// with `encode_masked` yields the exact bytes of the staged encoder, for
/// every encoding and cache state — pinning the stream-fed encoder
/// independently of the masker that normally feeds it.
#[test]
fn prop_stream_from_dense_encode_matches_staged_encoder() {
    check("from_dense + encode_masked == staged encoder", 60, |g| {
        let p = match g.usize_in(0, 9) {
            0 => 0,
            1 => 1,
            _ => g.usize_in(2, 1200),
        };
        let density = g.f32_in(0.0, 1.0);
        let params: Vec<f32> = (0..p)
            .map(|_| {
                if g.f32_in(0.0, 1.0) < density {
                    g.f32_in(-2.0, 2.0)
                } else {
                    0.0
                }
            })
            .collect();
        let cache = IndexCache::first((0..p as u32).filter(|_| g.bool()).collect());
        let mut stream = MaskedStream::default();
        stream.from_dense(&params);
        let mut scratch = EncodeScratch::default();
        let mut fused = Vec::new();
        for &enc in Encoding::ALL {
            for cached in [None, Some(&cache)] {
                let staged =
                    encode_update_cached_with(&mut scratch, 2, 9, 31, &params, enc, cached);
                encode_masked(&mut scratch, &mut fused, 2, 9, 31, &stream, enc, cached).unwrap();
                assert_eq!(
                    fused, staged,
                    "enc {enc:?} cache {} p {p} seed {:#x}",
                    cached.is_some(),
                    g.seed
                );
            }
        }
    });
}

#[test]
fn prop_hlo_mask_kernel_matches_rust_oracle() {
    // Needs artifacts; skip silently if absent.
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let Ok(manifest) = Manifest::load(&dir) else {
        eprintln!("skipping kernel-vs-oracle property (run `make artifacts`)");
        return;
    };
    let engine = fedmask::runtime::engine::Engine::load(&manifest, &["lenet"]).unwrap();
    let mm = engine.model("lenet").unwrap().clone();
    check("pallas kernel == rust oracle", 8, |g: &mut Gen| {
        let gamma = g.f32_in(0.05, 0.95);
        let wn = g.normal_vec(mm.p);
        let wo = g.normal_vec(mm.p);
        let hlo = engine.mask("lenet", &wn, &wo, gamma).unwrap();
        let oracle =
            masking::selective_mask_rust(&wn, &wo, gamma, &mm.layers, MaskScope::PerLayer);
        // compare kept sets per layer; bisection ties can differ by <=1
        // entry per layer at f32 resolution
        for l in &mm.layers {
            let seg = l.offset..l.offset + l.size;
            let kept_hlo = hlo[seg.clone()].iter().filter(|v| **v != 0.0).count();
            let kept_rust = oracle[seg.clone()].iter().filter(|v| **v != 0.0).count();
            assert!(
                (kept_hlo as isize - kept_rust as isize).abs() <= 2,
                "layer {} kept {kept_hlo} vs {kept_rust} (gamma {gamma}, seed {:#x})",
                l.name,
                g.seed
            );
            let disagree = hlo[seg.clone()]
                .iter()
                .zip(&oracle[seg])
                .filter(|(a, b)| (**a != 0.0) != (**b != 0.0))
                .count();
            assert!(
                disagree <= 2,
                "layer {}: {disagree} membership disagreements",
                l.name
            );
        }
    });
}

#[test]
fn prop_random_mask_rate_concentrates() {
    check("random mask rate", 30, |g| {
        let n = g.usize_in(5_000, 40_000);
        let gamma = g.f32_in(0.1, 0.9);
        let w = vec![1.0f32; n];
        let layers = vec![layer(0, n, true)];
        let mut rng = fedmask::sim::rng::Rng::new(g.seed);
        let masked = masking::random_mask_rust(&w, gamma, &layers, &mut rng);
        let kept = masked.iter().filter(|v| **v != 0.0).count() as f64 / n as f64;
        assert!((kept - gamma as f64).abs() < 0.03, "kept {kept} vs gamma {gamma}");
    });
}
