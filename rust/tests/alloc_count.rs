//! Allocation-count regression harness for the fused client hot path.
//!
//! The whole point of the fused mask→quantize→encode pipeline and the
//! shared [`BufferPool`] is that a **steady-state** round performs zero
//! heap allocation on the client encode side (mask → stream → frame) and
//! zero on the server fold side (decode view → sparse/dense fold → frame
//! returned to the pool). This test pins that claim with a counting
//! global allocator: after a warmup pass brings every scratch buffer to
//! its steady-state capacity, a full client-encode + server-fold cycle
//! across the wire encodings the upload path uses must allocate exactly
//! **zero** times.
//!
//! The harness lives in its own integration-test binary so the counting
//! allocator sees no other tests' traffic, and the one `#[test]` runs on
//! a single thread, so the count is deterministic. `unsafe` is required
//! by the `GlobalAlloc` contract and nothing else; the crate-wide
//! `unsafe_code = "deny"` lint is overridden for this file only.

#![allow(unsafe_code)]

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicUsize, Ordering};

use fedmask::fl::aggregate::{Contribution, SparseContribution, StreamingFedAvg};
use fedmask::fl::masking::{MaskScope, MaskScratch};
use fedmask::fl::pipeline::mask_stream_selective;
use fedmask::runtime::bufpool::BufferPool;
use fedmask::runtime::manifest::LayerInfo;
use fedmask::transport::codec::{
    decode_update_view_cached, encode_masked, BodyView, DecodeScratch, EncodeScratch, Encoding,
    MaskedStream,
};
use fedmask::transport::session::IndexCache;

/// Counts every allocation (fresh, zeroed, and growth reallocs) passing
/// through the global allocator. Frees are deliberately not counted: the
/// invariant under test is "no allocation", not "balanced allocation".
struct CountingAlloc;

static ALLOCS: AtomicUsize = AtomicUsize::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        unsafe { System.alloc(layout) }
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        unsafe { System.dealloc(ptr, layout) }
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        unsafe { System.alloc_zeroed(layout) }
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        unsafe { System.realloc(ptr, layout, new_size) }
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

/// Everything one steady-state cycle touches, owned across iterations the
/// way a worker thread (encode side) and the round driver (fold side)
/// own their scratch across rounds.
struct Bench {
    wn: Vec<f32>,
    wo: Vec<f32>,
    layers: Vec<LayerInfo>,
    p: usize,
    cache: IndexCache,
    pool: BufferPool,
    mask: MaskScratch,
    stream: MaskedStream,
    enc: EncodeScratch,
    dec: DecodeScratch,
    agg: StreamingFedAvg,
}

impl Bench {
    /// One full client-encode + server-fold cycle: check a frame out of
    /// the pool, fused mask+encode into it, decode it as a borrowed view,
    /// fold, return the frame. This is exactly the dance `ClientJob::run`
    /// and the serial drain loop perform per upload.
    fn cycle(&mut self, enc: Encoding, scope: MaskScope, with_cache: bool) {
        let cache = if with_cache { Some(&self.cache) } else { None };
        let mut payload = self.pool.take();
        mask_stream_selective(
            &self.wn,
            &self.wo,
            0.3,
            &self.layers,
            scope,
            &mut self.mask,
            &mut self.stream,
        )
        .expect("regular layer table");
        encode_masked(&mut self.enc, &mut payload, 1, 1, 10, &self.stream, enc, cache)
            .expect("finite values");
        let view =
            decode_update_view_cached(&payload, &mut self.dec, cache).expect("own bytes decode");
        match view.body {
            BodyView::Dense(d) => self
                .agg
                .fold(Contribution { client: 1, params: d, n_samples: 10 })
                .expect("dense fold"),
            BodyView::Sparse { indices, values } => self
                .agg
                .fold_sparse(SparseContribution {
                    client: 1,
                    p: self.p,
                    indices,
                    values,
                    n_samples: 10,
                })
                .expect("sparse fold"),
        }
        self.pool.put(payload);
    }
}

/// The upload-path encodings a steady-state client actually selects
/// among, paired with whether the cycle runs against the session's
/// cross-round index cache (the `SparseCached` arm requires it).
const ARMS: &[(Encoding, bool)] = &[
    (Encoding::Dense, false),
    (Encoding::Auto, false),
    (Encoding::AutoQ8, false),
    (Encoding::AutoQ4, false),
    (Encoding::GroupedQ8, false),
    (Encoding::SparseCached, true),
];

#[test]
fn steady_state_encode_and_fold_allocate_zero() {
    let p = 4096usize;
    // two masked tensors and an unmasked bias tail, like a real manifest
    let layers = vec![
        LayerInfo { name: "w0".into(), shape: vec![1800], offset: 0, size: 1800, masked: true },
        LayerInfo { name: "w1".into(), shape: vec![1800], offset: 1800, size: 1800, masked: true },
        LayerInfo { name: "b".into(), shape: vec![496], offset: 3600, size: 496, masked: false },
    ];
    // deterministic, allocation-free value streams (no RNG state)
    let wo: Vec<f32> = (0..p).map(|i| (i as f32 * 0.37).sin()).collect();
    let wn: Vec<f32> = (0..p).map(|i| (i as f32 * 0.37).sin() + (i as f32 * 0.91).cos() * 0.1).collect();

    // the cache a previous accepted round would have left behind: this
    // round's own support, so the SparseCached arm wins its size race
    let mut mask = MaskScratch::default();
    let mut stream = MaskedStream::default();
    mask_stream_selective(&wn, &wo, 0.3, &layers, MaskScope::PerLayer, &mut mask, &mut stream)
        .expect("regular layer table");
    let cache = IndexCache::first(stream.indices().to_vec());

    let mut bench = Bench {
        wn,
        wo,
        layers,
        p,
        cache,
        pool: BufferPool::new(),
        mask,
        stream,
        enc: EncodeScratch::default(),
        dec: DecodeScratch::default(),
        agg: StreamingFedAvg::new(p),
    };

    // Warmup: grow every scratch/pool buffer to steady-state capacity.
    // Three passes so growth that feeds on a previous pass's result (e.g.
    // pooled frame capacity across encodings of different sizes) settles.
    for _ in 0..3 {
        for &(enc, with_cache) in ARMS {
            for scope in [MaskScope::PerLayer, MaskScope::Global] {
                bench.cycle(enc, scope, with_cache);
            }
        }
    }

    // Measured steady state. A miss on the first attempt is treated as
    // residual warmup (some capacity settled late) and retried; the final
    // attempt must be exactly zero.
    let mut last = usize::MAX;
    for _attempt in 0..3 {
        let before = ALLOCS.load(Ordering::Relaxed);
        for _ in 0..5 {
            for &(enc, with_cache) in ARMS {
                for scope in [MaskScope::PerLayer, MaskScope::Global] {
                    bench.cycle(enc, scope, with_cache);
                }
            }
        }
        last = ALLOCS.load(Ordering::Relaxed) - before;
        if last == 0 {
            break;
        }
    }
    assert_eq!(
        last, 0,
        "steady-state fused encode + fold must not touch the heap \
         ({last} allocations across 5 warm cycles of {} arms)",
        ARMS.len() * 2
    );
}
