//! fedlint golden tests: each rule is run against a miniature fixture
//! repo under `tests/fedlint_fixtures/<rule>/` whose seeded violations
//! must produce exactly the expected diagnostics (and whose allowlisted
//! lines must stay suppressed), plus a self-scan asserting the full pass
//! is clean on this repository itself.

use std::path::Path;

use fedmask::lint::{
    self, config_drift, lock_order, panic_free, pre_decode, source, wire_spec, Diagnostic,
    SourceTree,
};

fn fixture(rule: &str) -> SourceTree {
    let root = Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("tests/fedlint_fixtures")
        .join(rule);
    SourceTree::load(&root).expect("fixture tree loads")
}

/// 1-based line of the first occurrence of `needle` in the fixture file
/// with path suffix `suffix`.
fn line(tree: &SourceTree, suffix: &str, needle: &str) -> usize {
    tree.file(suffix)
        .unwrap_or_else(|| panic!("fixture has no file ending {suffix}"))
        .find_line(needle)
        .unwrap_or_else(|| panic!("{suffix} does not contain {needle:?}"))
}

fn diag(file: &str, line: usize, rule: &'static str, message: impl Into<String>) -> Diagnostic {
    Diagnostic {
        file: file.to_string(),
        line,
        rule,
        message: message.into(),
    }
}

#[test]
fn wire_spec_fires_on_drift_in_both_directions() {
    let tree = fixture("wire_spec");
    let diags = lint::apply_allowlist(&tree, wire_spec::check(&tree));
    let wire = "rust/docs/WIRE.md";
    let codec = "rust/src/transport/codec.rs";
    assert_eq!(
        diags,
        vec![
            diag(
                wire,
                line(&tree, wire, "`0x4c47`"),
                wire_spec::RULE,
                "frame `magic` row does not mention `0x4c46` (frame.rs FRAME_MAGIC)",
            ),
            diag(
                wire,
                line(&tree, wire, "`0` hello"),
                wire_spec::RULE,
                "frame `kind` row does not mention ``1` welcome` (frame.rs FrameKind)",
            ),
            diag(
                wire,
                line(&tree, wire, "| runes"),
                wire_spec::RULE,
                "stale entry: WIRE.md documents body tag 5 \
                 but codec.rs declares no TAG_ constant for it",
            ),
            diag(
                codec,
                line(&tree, codec, "TAG_GHOST"),
                wire_spec::RULE,
                "`TAG_GHOST` (= 9) is not documented in any WIRE.md body-tag table",
            ),
        ]
    );
}

#[test]
fn pre_decode_requires_a_preceding_guard() {
    let tree = fixture("pre_decode");
    let raw = pre_decode::check(&tree);
    // three undisciplined decodes fire, including the annotated one...
    assert_eq!(raw.len(), 3);
    // ...and the annotation suppresses exactly its own
    let handler = "rust/src/handler.rs";
    let msg = |name: &str| {
        format!(
            "fn `{name}` handles a Frame but decodes the payload without a \
             preceding validate_upload() (WIRE.md §1b pre-decode discipline)"
        )
    };
    assert_eq!(
        lint::apply_allowlist(&tree, raw),
        vec![
            diag(
                handler,
                line(&tree, handler, "decode_update(frame.body())"),
                pre_decode::RULE,
                msg("unguarded"),
            ),
            diag(
                handler,
                line(&tree, handler, "decode_update(frame.bytes())"),
                pre_decode::RULE,
                msg("guarded_late"),
            ),
        ]
    );
}

#[test]
fn panic_free_flags_every_token_class() {
    let tree = fixture("panic_free");
    let scope: panic_free::Scope = &[("danger.rs", Some(&["splat", "tidy", "vouched", "ghost"]))];
    let raw = panic_free::check_with(&tree, scope);
    // splat's four violations + vouched's annotated index + missing ghost
    assert_eq!(raw.len(), 6);
    let danger = "rust/src/danger.rs";
    assert_eq!(
        lint::apply_allowlist(&tree, raw),
        vec![
            diag(
                danger,
                1,
                panic_free::RULE,
                "scoped fn `ghost` not found — update lint::panic_free::SCOPE",
            ),
            diag(
                danger,
                line(&tree, danger, "v.first().unwrap()"),
                panic_free::RULE,
                "`.unwrap()` in panic-free fn `splat` — return a typed error instead",
            ),
            diag(
                danger,
                line(&tree, danger, ".expect("),
                panic_free::RULE,
                "`.expect(..)` in panic-free fn `splat` — return a typed error instead",
            ),
            diag(
                danger,
                line(&tree, danger, "v[2]"),
                panic_free::RULE,
                "direct indexing in panic-free fn `splat` — use .get(), patterns, or iterators",
            ),
            diag(
                danger,
                line(&tree, danger, "panic!("),
                panic_free::RULE,
                "`panic!` in panic-free fn `splat` — reject with a typed error instead",
            ),
        ]
    );
}

#[test]
fn config_drift_checks_every_door_of_the_surface() {
    let tree = fixture("config_drift");
    let table: &[config_drift::Entry] = &[
        config_drift::Entry {
            field: "clients",
            cli: Some("clients"),
            doc: Some("WIRE.md"),
        },
        config_drift::Entry {
            field: "rounds",
            cli: None,
            doc: Some("WIRE.md"),
        },
        config_drift::Entry {
            field: "lr",
            cli: Some("lr-override"),
            doc: None,
        },
        config_drift::Entry {
            field: "retired_knob",
            cli: None,
            doc: None,
        },
    ];
    let exp = "rust/src/config/experiment.rs";
    assert_eq!(
        lint::apply_allowlist(&tree, config_drift::check_with(&tree, table)),
        vec![
            diag(
                exp,
                1,
                config_drift::RULE,
                "stale entry: lint::config_drift::TABLE lists `retired_knob` \
                 but ExperimentConfig has no such field",
            ),
            diag(
                exp,
                line(&tree, exp, "pub rounds"),
                config_drift::RULE,
                "serde key \"rounds\" appears 1x in experiment.rs — need encode and decode",
            ),
            diag(
                exp,
                line(&tree, exp, "pub rounds"),
                config_drift::RULE,
                "config field `rounds` must be mentioned by name in docs/WIRE.md",
            ),
            diag(
                exp,
                line(&tree, exp, "pub lr"),
                config_drift::RULE,
                "config field `lr` declares CLI flag --lr-override, \
                 but no opt table quotes \"lr-override\"",
            ),
            diag(
                exp,
                line(&tree, exp, "pub mystery_knob"),
                config_drift::RULE,
                "unclassified config field `mystery_knob` — add it to lint::config_drift::TABLE",
            ),
        ]
    );
}

#[test]
fn lock_order_reports_the_cycle_and_spares_temporaries() {
    let tree = fixture("lock_order");
    let sock = "rust/src/transport/socket.rs";
    assert_eq!(
        lint::apply_allowlist(&tree, lock_order::check(&tree)),
        vec![
            diag(
                sock,
                line(&tree, sock, "let gb = self.b.lock()"),
                lock_order::RULE,
                "cyclic lock order: `b` acquired while holding `a` (fn `ab`), \
                 and another path acquires `a` while holding `b`",
            ),
            diag(
                sock,
                line(&tree, sock, "let ga2 = self.a.lock()"),
                lock_order::RULE,
                "cyclic lock order: `a` acquired while holding `b` (fn `ba`), \
                 and another path acquires `b` while holding `a`",
            ),
        ]
    );
}

#[test]
fn malformed_annotations_fire_and_never_suppress() {
    let tree = fixture("allowlist");
    let annot = "rust/src/annot.rs";
    let mut raw = source::check_annotations(&tree);
    let scope: panic_free::Scope = &[("annot.rs", None)];
    raw.extend(panic_free::check_with(&tree, scope));
    let index_line = line(&tree, annot, "v[0]");
    assert_eq!(
        lint::apply_allowlist(&tree, raw),
        vec![
            diag(
                annot,
                line(&tree, annot, "let x = 1;"),
                source::ALLOWLIST_RULE,
                "allow(panic-free) missing ` -- <reason>`",
            ),
            diag(
                annot,
                line(&tree, annot, "let y = 2;"),
                source::ALLOWLIST_RULE,
                "allow() names unknown rule 'not-a-rule'",
            ),
            diag(
                annot,
                index_line - 1,
                source::ALLOWLIST_RULE,
                "allow(panic-free) missing ` -- <reason>`",
            ),
            // the reasonless annotation above it does NOT suppress this
            diag(
                annot,
                index_line,
                panic_free::RULE,
                "direct indexing in panic-free fn `g` — use .get(), patterns, or iterators",
            ),
        ]
    );
}

/// The acceptance gate: the full pass over this repository itself is
/// clean. Any new finding must be fixed or allowlisted with a reason.
#[test]
fn repository_passes_its_own_lint() {
    let root = Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .expect("crate lives under the repo root")
        .to_path_buf();
    let tree = SourceTree::load(&root).expect("repo tree loads");
    let diags = lint::run(&tree);
    let rendered: Vec<String> = diags
        .iter()
        .map(|d| format!("{}:{}: [{}] {}", d.file, d.line, d.rule, d.message))
        .collect();
    assert!(
        diags.is_empty(),
        "fedlint findings on the repository itself:\n{}",
        rendered.join("\n")
    );
}
