//! Wire v3 codec torture suite: the cross-round index cache and the
//! entropy-coded value arms, pinned at the codec + fold level.
//!
//! Two contracts (see `docs/WIRE.md` §3b/§4):
//!
//! * **Cache coherence** — over multiple rounds of evolving masks
//!   (churn 0%, churn 100%, k growing and shrinking), a stateful
//!   `SparseCached` decode is bitwise-equal to the stateless
//!   `SparseDelta` decode of the same update, and the folded aggregate
//!   is bitwise-identical across both mask targets and shard counts
//!   {1, 8}.
//! * **Strict rejection** — a desynced or malformed payload is a typed
//!   parse error, never a wrong decode: stale/future cache epochs,
//!   removed indices the cached set does not hold, added indices that
//!   collide with it, truncated/overlong Rice streams and non-zero
//!   padding bits all die before anything folds, and the session cache
//!   is bit-identical before and after every rejected decode.
//!
//! Everything here is engine-free. The end-to-end cache *lifecycle*
//! (invalidation on drop/disconnect/skip) is pinned by the driver unit
//! tests and `tests/chaos_scenarios.rs`; this suite owns the wire
//! format itself.

use std::sync::Arc;

use fedmask::config::experiment::AggregatorKind;
use fedmask::fl::aggregate::{make_aggregator, Contribution, SparseContribution};
use fedmask::fl::masking::MaskTarget;
use fedmask::fl::tree::ShardedAggregator;
use fedmask::runtime::manifest::LayerInfo;
use fedmask::transport::codec::{
    decode_update, decode_update_cached, encode_update, encode_update_cached, DecodedBody,
    Encoding, WireUpdate, TAG_SPARSE_CACHED, TAG_SPARSE_DELTA, TAG_SPARSE_RICE8,
};
use fedmask::transport::session::IndexCache;
use fedmask::Error;

const P: usize = 64;

fn one_layer(size: usize) -> Vec<LayerInfo> {
    vec![LayerInfo {
        name: "w".into(),
        shape: vec![size],
        offset: 0,
        size,
        masked: true,
    }]
}

fn broadcast(p: usize) -> Vec<f32> {
    (0..p).map(|j| (j as f32 * 0.29).cos()).collect()
}

/// Dense update carrying a deterministic, provably non-zero value at
/// each support index (so the encoder's census sees exactly `support`).
fn update_on(support: &[u32], p: usize, round: u32) -> Vec<f32> {
    let mut v = vec![0.0f32; p];
    for &j in support {
        v[j as usize] = 1.0 + j as f32 * 0.01 + round as f32 * 0.1;
    }
    v
}

fn sparse_of(u: &WireUpdate) -> (Vec<u32>, Vec<f32>) {
    match &u.body {
        DecodedBody::Sparse { indices, values } => (indices.clone(), values.clone()),
        DecodedBody::Dense(_) => panic!("expected a sparse body"),
    }
}

/// Serial reference fold: decode every payload (with its session cache)
/// and stream it into one aggregator.
fn fold_serial(
    payloads: &[(Vec<u8>, Option<IndexCache>)],
    target: MaskTarget,
    global: &[f32],
    layers: &[LayerInfo],
) -> Vec<f32> {
    let mut agg = make_aggregator(AggregatorKind::FedAvg, target, global, layers).unwrap();
    for (bytes, cache) in payloads {
        let u = decode_update_cached(bytes, cache.as_ref()).unwrap();
        match &u.body {
            DecodedBody::Dense(v) => agg
                .fold(Contribution {
                    client: u.client as usize,
                    params: v,
                    n_samples: u.n_samples,
                })
                .unwrap(),
            DecodedBody::Sparse { indices, values } => agg
                .fold_sparse(SparseContribution {
                    client: u.client as usize,
                    p: u.p,
                    indices,
                    values,
                    n_samples: u.n_samples,
                })
                .unwrap(),
        }
    }
    agg.finish().unwrap()
}

/// The same fold routed through the shard tree — each payload decodes on
/// a worker thread against the cache shipped alongside it.
fn fold_sharded(
    payloads: &[(Vec<u8>, Option<IndexCache>)],
    shards: usize,
    target: MaskTarget,
    global: &[f32],
    layers: &[LayerInfo],
) -> Vec<f32> {
    let partials = (0..shards)
        .map(|_| make_aggregator(AggregatorKind::FedAvg, target, global, layers))
        .collect::<fedmask::Result<Vec<_>>>()
        .unwrap();
    let mut tree = ShardedAggregator::spawn(partials).unwrap();
    for (bytes, cache) in payloads {
        let client = fedmask::transport::codec::peek_client(bytes).unwrap();
        tree.route(client, bytes.clone(), cache.clone().map(Arc::new)).unwrap();
    }
    tree.finish().unwrap()
}

// ---------------------------------------------------------------------
// Cache coherence: stateful == stateless, five-plus rounds of churn
// ---------------------------------------------------------------------

/// The per-client mask schedule the property walks: identical support
/// (churn 0%), a disjoint residue class (churn 100%), k doubling, k
/// collapsing to 3, then partial churn at the small k.
fn support_schedule(c: u32) -> Vec<Vec<u32>> {
    let p = P as u32;
    vec![
        (0..p).filter(|j| j % 4 == c % 4).collect(),
        (0..p).filter(|j| j % 4 == c % 4).collect(),
        (0..p).filter(|j| j % 4 == (c + 1) % 4).collect(),
        (0..p).filter(|j| j % 2 == c % 2).collect(),
        vec![c, c + 8, c + 16],
        vec![c, c + 8, c + 17, c + 40],
    ]
}

/// Six rounds, five clients, every churn regime: the stateful decode is
/// bitwise the stateless one, per payload and through the fold, for both
/// mask targets and shard counts {1, 8}. Caches advance every round here
/// (every fold "accepted"); rejection-driven invalidation is the chaos
/// suite's job.
#[test]
fn cached_decode_is_bitwise_equal_to_stateless_across_churn_regimes() {
    let clients: Vec<u32> = (0..5).collect();
    let layers = one_layer(P);
    let global = broadcast(P);
    let mut caches: Vec<Option<IndexCache>> = vec![None; clients.len()];

    for r in 0..6usize {
        let round = (r + 1) as u32;
        let mut payloads: Vec<(Vec<u8>, Option<IndexCache>)> = Vec::new();
        let mut stateless_payloads: Vec<(Vec<u8>, Option<IndexCache>)> = Vec::new();
        for (i, &c) in clients.iter().enumerate() {
            let support = &support_schedule(c)[r];
            let update = update_on(support, P, round);
            let stateless =
                encode_update(c, round, 10 + c, &update, Encoding::SparseDelta);
            let cached = encode_update_cached(
                c,
                round,
                10 + c,
                &update,
                Encoding::SparseCached,
                caches[i].as_ref(),
            );

            // tag economics where they are pinned: no cache means a full
            // index send; a zero-churn round at k=16 must go stateful
            // (12-byte epoch/count overhead < 16 index bytes)
            if r == 0 {
                assert_eq!(cached[3], TAG_SPARSE_DELTA, "round 1 must be stateless");
            }
            if r == 1 {
                assert_eq!(cached[3], TAG_SPARSE_CACHED, "zero churn at k=16 must cache");
            }

            // per-payload bitwise equality, sparse view and densified
            let a = decode_update(&stateless).unwrap();
            let b = decode_update_cached(&cached, caches[i].as_ref()).unwrap();
            assert_eq!(
                sparse_of(&a),
                sparse_of(&b),
                "client {c} round {round}: stateful decode != stateless"
            );
            assert_eq!(a.clone().into_dense(), b.into_dense());

            payloads.push((cached, caches[i].clone()));
            stateless_payloads.push((stateless, None));
            caches[i] = Some(match &caches[i] {
                Some(prev) => prev.advance(support.clone()),
                None => IndexCache::first(support.clone()),
            });
        }

        // fold equality: serial stateless reference vs serial cached vs
        // the shard tree at 1 and 8 shards
        for target in [MaskTarget::Delta, MaskTarget::Weights] {
            let reference = fold_serial(&stateless_payloads, target, &global, &layers);
            assert_eq!(
                reference,
                fold_serial(&payloads, target, &global, &layers),
                "{target:?} round {round}: serial cached fold diverged"
            );
            for shards in [1usize, 8] {
                assert_eq!(
                    reference,
                    fold_sharded(&payloads, shards, target, &global, &layers),
                    "{target:?} round {round}: {shards}-shard cached fold diverged"
                );
            }
        }
    }
}

// ---------------------------------------------------------------------
// Rejection corpus: every desync is a typed parse error, cache untouched
// ---------------------------------------------------------------------

/// A canonical tag-7 payload: zero churn against a 16-index first-epoch
/// cache (large enough that the cached arm wins the length census).
fn cached_fixture() -> (Vec<u8>, IndexCache) {
    let support: Vec<u32> = (0..P as u32).filter(|j| j % 4 == 0).collect();
    let cache = IndexCache::first(support.clone());
    let update = update_on(&support, P, 2);
    let payload = encode_update_cached(7, 2, 42, &update, Encoding::SparseCached, Some(&cache));
    assert_eq!(payload[3], TAG_SPARSE_CACHED, "fixture must exercise the cached arm");
    (payload, cache)
}

/// Assert `payload` dies with a typed parse error under `cache`, that
/// the cache is bit-identical afterwards, and that the same cache still
/// decodes the known-good payload (no poisoned state anywhere).
fn assert_rejected(payload: &[u8], cache: &IndexCache, good: &[u8], what: &str) {
    let before = cache.clone();
    let err = decode_update_cached(payload, Some(cache))
        .expect_err(&format!("{what}: malformed payload must not decode"));
    assert!(matches!(err, Error::Parse(_)), "{what}: want a parse error, got {err}");
    assert_eq!(*cache, before, "{what}: rejected decode mutated the cache");
    decode_update_cached(good, Some(cache))
        .unwrap_or_else(|e| panic!("{what}: cache poisoned for later decodes: {e}"));
}

#[test]
fn tag7_without_a_session_cache_is_rejected() {
    let (payload, cache) = cached_fixture();
    let err = decode_update(&payload).expect_err("stateless decode of tag 7 must fail");
    assert!(matches!(err, Error::Parse(_)), "want a parse error, got {err}");
    // with the cache it still decodes — the payload itself is fine
    decode_update_cached(&payload, Some(&cache)).unwrap();
}

#[test]
fn stale_and_future_cache_epochs_are_rejected() {
    let (payload, cache) = cached_fixture();
    // stale payload: the session advanced past the epoch it names
    let advanced = cache.advance(cache.indices.clone());
    let update = update_on(&cache.indices, P, 3);
    let good_for_advanced =
        encode_update_cached(7, 3, 42, &update, Encoding::SparseCached, Some(&advanced));
    assert_rejected(&payload, &advanced, &good_for_advanced, "stale epoch");
    // future payload: epoch bytes (body offset 0 = byte 24) forged ahead
    // of the session's
    let mut forged = payload.clone();
    forged[24..28].copy_from_slice(&5u32.to_le_bytes());
    assert_rejected(&forged, &cache, &payload, "future epoch");
}

#[test]
fn removed_index_not_in_cached_set_is_rejected() {
    // encode against a cache holding index 0, so the delta removes 0 …
    let enc_cache = IndexCache::first((0..P as u32).filter(|j| j % 4 == 0).collect());
    let support: Vec<u32> = enc_cache.indices[1..].to_vec();
    let update = update_on(&support, P, 2);
    let payload =
        encode_update_cached(7, 2, 42, &update, Encoding::SparseCached, Some(&enc_cache));
    assert_eq!(payload[3], TAG_SPARSE_CACHED);
    // … and decode against a same-shape cache that never held 0
    let mut indices = enc_cache.indices.clone();
    indices[0] = 2;
    let desynced = IndexCache { epoch: enc_cache.epoch, indices };
    let good = {
        let u = update_on(&desynced.indices[1..].to_vec(), P, 2);
        encode_update_cached(7, 2, 42, &u, Encoding::SparseCached, Some(&desynced))
    };
    assert_rejected(&payload, &desynced, &good, "removed index not in cached set");
}

#[test]
fn added_index_colliding_with_cached_set_is_rejected() {
    // encode against a cache without index 2, so the delta adds 2 …
    let enc_cache = IndexCache::first((0..P as u32).filter(|j| j % 4 == 0).collect());
    let mut support = enc_cache.indices.clone();
    support.insert(1, 2);
    let update = update_on(&support, P, 2);
    let payload =
        encode_update_cached(7, 2, 42, &update, Encoding::SparseCached, Some(&enc_cache));
    assert_eq!(payload[3], TAG_SPARSE_CACHED);
    // … and decode against a cache that already holds 2
    let mut indices = enc_cache.indices.clone();
    indices[0] = 2;
    let desynced = IndexCache { epoch: enc_cache.epoch, indices };
    let good = {
        let u = update_on(&desynced.indices, P, 2);
        encode_update_cached(7, 2, 42, &u, Encoding::SparseCached, Some(&desynced))
    };
    assert_rejected(&payload, &desynced, &good, "added index collides with cached set");
}

#[test]
fn truncated_and_overlong_cached_payloads_are_rejected() {
    let (payload, cache) = cached_fixture();
    let mut truncated = payload.clone();
    truncated.pop();
    assert_rejected(&truncated, &cache, &payload, "truncated cached payload");
    let mut overlong = payload.clone();
    overlong.push(0);
    assert_rejected(&overlong, &cache, &payload, "overlong cached payload");
}

// ---------------------------------------------------------------------
// Rice stream strictness (tag 10, AutoQ8's entropy-coded arm)
// ---------------------------------------------------------------------

/// An `AutoQ8` payload whose length census picks the Rice arm: 9 equal
/// values over p=64 quantize to all-zero codes, so k=0 and the coded
/// stream is 9 bits — two bytes, seven of them padding.
fn rice_fixture() -> Vec<u8> {
    let support: Vec<u32> = (0..9u32).map(|i| i * 4).collect();
    let mut update = vec![0.0f32; P];
    for &j in &support {
        update[j as usize] = 0.5;
    }
    let payload = encode_update(7, 2, 42, &update, Encoding::AutoQ8);
    assert_eq!(payload[3], TAG_SPARSE_RICE8, "fixture must exercise the Rice arm");
    decode_update(&payload).unwrap();
    payload
}

fn assert_rice_rejected(mutated: &[u8], what: &str) {
    let err =
        decode_update(mutated).expect_err(&format!("{what}: malformed payload must not decode"));
    assert!(matches!(err, Error::Parse(_)), "{what}: want a parse error, got {err}");
}

#[test]
fn rice_stream_mutations_are_rejected() {
    let payload = rice_fixture();

    let mut truncated = payload.clone();
    truncated.pop();
    assert_rice_rejected(&truncated, "truncated rice stream");

    let mut overlong = payload.clone();
    overlong.push(0);
    assert_rice_rejected(&overlong, "overlong rice stream");

    // bits are packed LSB-first, so bit 7 of the final byte is padding
    // for any coded stream whose length is not a multiple of 8 bits
    let mut padded = payload.clone();
    *padded.last_mut().unwrap() |= 0x80;
    assert_rice_rejected(&padded, "non-zero rice padding");
}
