// panic_free fixture: one fn per outcome. The test scopes this file with
// an extra name ("ghost") to prove the missing-fn diagnostic fires.

pub fn splat(v: &[u8]) -> u8 {
    let first = v.first().unwrap();
    let second = v.get(1).expect("second byte");
    let third = v[2];
    if *first > 9 {
        panic!("too big");
    }
    *first + *second + third
}

pub fn tidy(v: &[u8]) -> u8 {
    let arr: [u8; 2] = [0, 1];
    let head = v.first().copied().unwrap_or(0);
    head + arr.iter().sum::<u8>()
}

pub fn vouched(v: &[u8]) -> u8 {
    // fedlint: allow(panic-free) -- fixture: caller checks v is non-empty
    v[0]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn test_tokens_here_do_not_count() {
        let v = vec![3u8];
        let _ = v[0];
        v.first().unwrap();
    }
}
