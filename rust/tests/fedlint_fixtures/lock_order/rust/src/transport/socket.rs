// lock_order fixture: `ab` and `ba` take the two mutexes in opposite
// orders (the cycle this rule exists to catch); `peek` only ever holds
// one guard as a chained temporary and must stay clean.

use std::sync::Mutex;

pub struct S {
    a: Mutex<u32>,
    b: Mutex<u32>,
}

impl S {
    pub fn ab(&self) -> u32 {
        let ga = self.a.lock().unwrap();
        let gb = self.b.lock().unwrap();
        *ga + *gb
    }

    pub fn ba(&self) -> u32 {
        let gb2 = self.b.lock().unwrap();
        let ga2 = self.a.lock().unwrap();
        *ga2 + *gb2
    }

    pub fn peek(&self) -> u32 {
        *self.a.lock().unwrap() + *self.b.lock().unwrap()
    }
}
