// allowlist fixture: one annotation without a reason, one naming an
// unknown rule. Neither may suppress anything.

pub fn f() -> u32 {
    let x = 1; // fedlint: allow(panic-free)
    let y = 2; // fedlint: allow(not-a-rule) -- the rule does not exist
    x + y
}

pub fn g(v: &[u8]) -> u8 {
    // fedlint: allow(panic-free)
    v[0]
}
