// pre_decode fixture: Frame-handling fns in every guard configuration.

pub fn guarded(frame: &Frame) -> Result<Vec<f32>> {
    validate_upload(frame)?;
    decode_update(frame.payload())
}

pub fn unguarded(frame: &Frame) -> Result<Vec<f32>> {
    decode_update(frame.body())
}

pub fn guarded_late(frame: &Frame) -> Result<Vec<f32>> {
    let out = decode_update(frame.bytes());
    validate_upload(frame)?;
    out
}

pub fn vouched_elsewhere(frame: &Frame) -> Result<Vec<f32>> {
    // fedlint: allow(pre-decode) -- fixture: loopback frame, payload is ours
    decode_update(frame.loopback())
}

pub fn not_a_frame(kind: FrameKind, bytes: &[u8]) -> Result<Vec<f32>> {
    let _ = kind;
    decode_update(bytes)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn raw_decode(frame: &Frame) -> Result<Vec<f32>> {
        decode_update(frame.payload())
    }
}
