// wire_spec fixture: TAG_GHOST is deliberately undocumented, and the
// doc's tag-5 row is deliberately stale.

pub const MAGIC: u16 = 0x464d;
pub const VERSION: u8 = 1;
pub const HEADER_BYTES: usize = 24;

pub const TAG_DENSE: u8 = 0;
pub const TAG_Q8: u8 = 1;
pub const TAG_GHOST: u8 = 9;
