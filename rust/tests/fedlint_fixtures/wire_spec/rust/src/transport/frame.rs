// wire_spec fixture: constants the doc tables must agree with. The doc
// states the wrong magic and omits the `welcome` kind on purpose.

pub const FRAME_MAGIC: u16 = 0x4c46;
pub const FRAME_VERSION: u8 = 2;
pub const FRAME_HEADER_BYTES: usize = 16;
pub const MAX_FRAME_BYTES: usize = 64 << 20;

pub enum FrameKind {
    Hello = 0,
    Welcome = 1,
}
