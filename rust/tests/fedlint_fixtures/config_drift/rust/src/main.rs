// config_drift fixture CLI: quotes one flag only — the clients override.
// The lr override flag is deliberately absent.

fn main() {
    let opts = [("clients", "number of simulated clients")];
    let _ = opts;
}
