// config_drift fixture: `rounds` loses its decode arm and its doc
// mention, `lr` declares a CLI flag no opt table quotes, and
// `mystery_knob` is not classified in the test's registry.

pub struct ExperimentConfig {
    pub clients: usize,
    pub rounds: usize,
    pub lr: f32,
    pub mystery_knob: f32,
}

impl ExperimentConfig {
    pub fn encode(&self) -> Vec<(&'static str, String)> {
        vec![
            ("clients", self.clients.to_string()),
            ("rounds", self.rounds.to_string()),
            ("lr", self.lr.to_string()),
            ("mystery_knob", self.mystery_knob.to_string()),
        ]
    }

    pub fn decode(kv: &[(&str, &str)]) -> ExperimentConfig {
        let mut c = ExperimentConfig {
            clients: 0,
            rounds: 0,
            lr: 0.0,
            mystery_knob: 0.0,
        };
        for (k, v) in kv {
            match *k {
                "clients" => c.clients = v.parse().unwrap_or(0),
                "lr" => c.lr = v.parse().unwrap_or(0.0),
                "mystery_knob" => c.mystery_knob = v.parse().unwrap_or(0.0),
                _ => {}
            }
        }
        c
    }
}
