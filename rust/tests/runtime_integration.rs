//! Integration tests: the PJRT engine against the real AOT artifacts.
//!
//! These require `make artifacts` to have run (they are skipped with a clear
//! message otherwise, so `cargo test` stays usable before the first build).

use fedmask::runtime::engine::Engine;
use fedmask::runtime::manifest::Manifest;
use fedmask::runtime::pool::EnginePool;
use fedmask::runtime::tensor::{Batches, XData};
use fedmask::sim::rng::Rng;

fn manifest() -> Option<Manifest> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    match Manifest::load(&dir) {
        Ok(m) => Some(m),
        Err(e) => {
            eprintln!("skipping runtime integration test (run `make artifacts`): {e}");
            None
        }
    }
}

/// Synthetic learnable image chunk: 10 class templates + noise.
fn image_chunk(mm: &fedmask::runtime::manifest::ModelManifest, nb: usize, seed: u64) -> Batches {
    let mut rng = Rng::new(seed);
    let elem: usize = mm.x_elem_len();
    let templates: Vec<Vec<f32>> = (0..10)
        .map(|c| {
            let mut r = Rng::new(1000 + c);
            (0..elem).map(|_| r.next_normal()).collect()
        })
        .collect();
    let n = nb * mm.batch;
    let mut xs = Vec::with_capacity(n * elem);
    let mut ys = Vec::with_capacity(n);
    for _ in 0..n {
        let c = rng.next_below(10) as usize;
        ys.push(c as i32);
        for j in 0..elem {
            xs.push(templates[c][j] + 0.3 * rng.next_normal());
        }
    }
    Batches::new(
        nb,
        mm.batch,
        mm.x_elem_shape.clone(),
        mm.y_elem_shape.clone(),
        XData::F32(xs),
        ys,
    )
    .unwrap()
}

/// Synthetic LM chunk over a small vocab slice.
fn lm_chunk(mm: &fedmask::runtime::manifest::ModelManifest, nb: usize, seed: u64) -> Batches {
    let mut rng = Rng::new(seed);
    let seq = mm.x_elem_shape[0];
    let n = nb * mm.batch;
    let mut xs = Vec::with_capacity(n * seq);
    let mut ys = Vec::with_capacity(n * seq);
    for _ in 0..n {
        let mut tok = rng.next_below(50) as i32;
        for _ in 0..seq {
            xs.push(tok);
            // deterministic-ish successor structure makes it learnable
            let next = ((tok as u64 * 7 + 3) % 50) as i32;
            ys.push(next);
            tok = next;
        }
    }
    Batches::new(
        nb,
        mm.batch,
        mm.x_elem_shape.clone(),
        mm.y_elem_shape.clone(),
        XData::I32(xs),
        ys,
    )
    .unwrap()
}

#[test]
fn lenet_init_train_eval_mask_roundtrip() {
    let Some(manifest) = manifest() else { return };
    let engine = Engine::load(&manifest, &["lenet"]).unwrap();
    let mm = engine.model("lenet").unwrap().clone();

    // init: deterministic, right length, finite
    let p0 = engine.init("lenet", 42).unwrap();
    let p1 = engine.init("lenet", 42).unwrap();
    let p2 = engine.init("lenet", 7).unwrap();
    assert_eq!(p0.len(), mm.p);
    assert_eq!(p0, p1);
    assert_ne!(p0, p2);
    assert!(p0.iter().all(|v| v.is_finite()));

    // train: loss decreases over epochs on learnable data
    let chunk = image_chunk(&mm, mm.nb_train, 5);
    let (mut params, first_loss) = engine.train_epoch("lenet", &p0, &chunk, 0.05).unwrap();
    let mut last_loss = first_loss;
    for _ in 0..4 {
        let (np, loss) = engine.train_epoch("lenet", &params, &chunk, 0.05).unwrap();
        params = np;
        last_loss = loss;
    }
    assert!(
        last_loss < first_loss,
        "loss should fall: {first_loss} -> {last_loss}"
    );

    // eval: counts match geometry, accuracy improved over init
    let echunk = image_chunk(&mm, mm.nb_eval, 99);
    let before = engine.eval_chunk("lenet", &p0, &echunk).unwrap();
    let after = engine.eval_chunk("lenet", &params, &echunk).unwrap();
    assert_eq!(before.count as usize, mm.eval_chunk_samples());
    assert!(after.accuracy() > before.accuracy());

    // mask: keep-rate per maskable layer, biases untouched
    let gamma = 0.3f32;
    let masked = engine.mask("lenet", &params, &p0, gamma).unwrap();
    assert_eq!(masked.len(), mm.p);
    for l in &mm.layers {
        let seg = &masked[l.offset..l.offset + l.size];
        let orig = &params[l.offset..l.offset + l.size];
        if l.masked {
            let kept = seg.iter().filter(|v| **v != 0.0).count();
            let k = (gamma * l.size as f32).round() as isize;
            assert!(
                (kept as isize - k).abs() <= (l.size as isize / 50).max(2),
                "layer {} kept {kept} want ~{k}",
                l.name
            );
            // kept entries are w_new verbatim
            for (s, o) in seg.iter().zip(orig) {
                assert!(*s == 0.0 || s == o);
            }
        } else {
            assert_eq!(seg, orig, "unmasked layer {} must pass through", l.name);
        }
    }
}

#[test]
fn gru_lm_trains_and_perplexity_drops() {
    let Some(manifest) = manifest() else { return };
    let engine = Engine::load(&manifest, &["gru"]).unwrap();
    let mm = engine.model("gru").unwrap().clone();

    let p0 = engine.init("gru", 0).unwrap();
    let chunk = lm_chunk(&mm, mm.nb_train, 3);
    let echunk = lm_chunk(&mm, mm.nb_eval, 11);

    let before = engine.eval_chunk("gru", &p0, &echunk).unwrap();
    let mut params = p0;
    for _ in 0..3 {
        let (np, _) = engine.train_epoch("gru", &params, &chunk, 0.5).unwrap();
        params = np;
    }
    let after = engine.eval_chunk("gru", &params, &echunk).unwrap();
    assert!(
        after.perplexity() < before.perplexity(),
        "ppl should fall: {} -> {}",
        before.perplexity(),
        after.perplexity()
    );
    // initial ppl should be near uniform over vocab
    let vocab = mm.vocab().unwrap() as f64;
    assert!(before.perplexity() > vocab * 0.3);
}

#[test]
fn pool_parallel_training_matches_sequential() {
    let Some(manifest) = manifest() else { return };
    let pool = EnginePool::new(&manifest, &["lenet"], 2).unwrap();
    let engine = Engine::load(&manifest, &["lenet"]).unwrap();
    let mm = engine.model("lenet").unwrap().clone();

    let p0 = engine.init("lenet", 1).unwrap();
    let chunks: Vec<Batches> = (0..4).map(|i| image_chunk(&mm, mm.nb_train, 100 + i)).collect();

    // sequential reference
    let seq: Vec<Vec<f32>> = chunks
        .iter()
        .map(|c| engine.train_epoch("lenet", &p0, c, 0.05).unwrap().0)
        .collect();

    // pooled
    let jobs: Vec<_> = chunks
        .iter()
        .map(|c| {
            let p = p0.clone();
            let c = c.clone();
            move |e: &Engine| e.train_epoch("lenet", &p, &c, 0.05).unwrap().0
        })
        .collect();
    let par = pool.map(jobs).unwrap();

    assert_eq!(seq, par, "pool must be bit-identical to sequential");
}

#[test]
fn pool_map_unordered_yields_every_job_with_its_index() {
    let Some(manifest) = manifest() else { return };
    let pool = EnginePool::new(&manifest, &["lenet"], 3).unwrap();

    // Stagger job durations so completion order differs from input order;
    // the index channel must still attribute every result correctly.
    let jobs: Vec<_> = (0..8u64)
        .map(|i| {
            move |_e: &Engine| {
                std::thread::sleep(std::time::Duration::from_millis((8 - i) * 3));
                i * 10
            }
        })
        .collect();
    let mut got: Vec<(usize, u64)> = pool.map_unordered(jobs).iter().collect();
    assert_eq!(got.len(), 8, "channel must close after the last job");
    got.sort_unstable();
    for (slot, (idx, val)) in got.iter().enumerate() {
        assert_eq!(*idx, slot);
        assert_eq!(*val, slot as u64 * 10);
    }

    // Empty batches close immediately instead of hanging the caller.
    let none: Vec<fn(&Engine) -> u64> = Vec::new();
    assert_eq!(pool.map_unordered(none).iter().count(), 0);
}
