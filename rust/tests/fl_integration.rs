//! End-to-end federated-loop integration tests over the real artifacts.
//!
//! Small geometries (4–6 clients, 2–4 rounds) keep these fast while still
//! exercising the full path: partition -> broadcast -> local train (PJRT)
//! -> mask (Pallas kernel) -> encode -> aggregate -> evaluate.

use std::sync::Arc;

use fedmask::config::experiment::ExperimentConfig;
use fedmask::fl::aggregate::{
    weighted_mean, Aggregator, Contribution, SparseContribution, StreamingFedAvg,
};
use fedmask::fl::masking::MaskPolicy;
use fedmask::fl::sampling::SamplingSchedule;
use fedmask::fl::server::Server;
use fedmask::runtime::manifest::Manifest;
use fedmask::runtime::pool::EnginePool;
use fedmask::transport::codec::{decode_update, encode_update, DecodedBody, Encoding};

fn manifest() -> Option<Manifest> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    match Manifest::load(&dir) {
        Ok(m) => Some(m),
        Err(e) => {
            eprintln!("skipping fl integration test (run `make artifacts`): {e}");
            None
        }
    }
}

fn tiny_cfg(label: &str) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::defaults("lenet").unwrap();
    cfg.label = label.into();
    cfg.clients = 4;
    cfg.rounds = 3;
    cfg.n_train = 1_024;
    cfg.n_test = 512;
    cfg.eval_max_chunks = 1;
    cfg.workers = 2;
    cfg.seed = 7;
    cfg
}

#[test]
fn federated_training_improves_accuracy_and_accounts_cost() {
    let Some(manifest) = manifest() else { return };
    let cfg = tiny_cfg("e2e-static");
    let rounds = cfg.rounds;
    let clients = cfg.clients;
    let outcome = Server::new(cfg, &manifest).unwrap().run().unwrap();

    let rec = &outcome.recorder;
    assert_eq!(rec.rounds.len(), rounds);
    // accuracy after training beats the 10-class prior comfortably
    let final_acc = rec.final_accuracy();
    assert!(final_acc > 0.3, "final accuracy too low: {final_acc}");
    // every round aggregated all clients (static C = 1.0)
    assert!(rec.rounds.iter().all(|r| r.clients == clients));
    // unmasked uploads: exactly clients * rounds full-model units
    let units = outcome.ledger.uplink_units;
    assert!(
        (units - (clients * rounds) as f64).abs() < 1e-9,
        "uplink units {units}"
    );
    assert_eq!(outcome.ledger.messages as usize, 2 * clients * rounds);
    assert!(outcome.final_params.iter().all(|v| v.is_finite()));
}

#[test]
fn dynamic_sampling_costs_less_than_static() {
    let Some(manifest) = manifest() else { return };
    let pool = Arc::new(EnginePool::new(&manifest, &["lenet"], 2).unwrap());

    let mut st = tiny_cfg("static");
    st.rounds = 4;
    let static_out = Server::with_pool(st, &manifest, Arc::clone(&pool))
        .unwrap()
        .run()
        .unwrap();

    let mut dy = tiny_cfg("dynamic");
    dy.rounds = 4;
    dy.sampling = SamplingSchedule::DynamicExp { c0: 1.0, beta: 0.5 };
    dy.min_clients = 2;
    let dynamic_out = Server::with_pool(dy, &manifest, pool).unwrap().run().unwrap();

    assert!(
        dynamic_out.ledger.uplink_units < static_out.ledger.uplink_units,
        "dynamic {} should cost less than static {}",
        dynamic_out.ledger.uplink_units,
        static_out.ledger.uplink_units
    );
    // and the sampled client counts decay but respect the floor of 2
    let counts: Vec<usize> = dynamic_out.recorder.rounds.iter().map(|r| r.clients).collect();
    assert!(counts.windows(2).all(|w| w[1] <= w[0]));
    assert!(counts.iter().all(|&c| c >= 2));
}

#[test]
fn selective_masking_cuts_uplink_bytes() {
    let Some(manifest) = manifest() else { return };
    let pool = Arc::new(EnginePool::new(&manifest, &["lenet"], 2).unwrap());

    let mut dense = tiny_cfg("dense");
    dense.rounds = 2;
    let dense_out = Server::with_pool(dense, &manifest, Arc::clone(&pool))
        .unwrap()
        .run()
        .unwrap();

    let mut masked = tiny_cfg("masked");
    masked.rounds = 2;
    masked.masking = MaskPolicy::selective(0.2);
    let masked_out = Server::with_pool(masked, &manifest, pool).unwrap().run().unwrap();

    assert!(
        (masked_out.ledger.uplink_bytes as f64) < 0.5 * dense_out.ledger.uplink_bytes as f64,
        "masked bytes {} vs dense {}",
        masked_out.ledger.uplink_bytes,
        dense_out.ledger.uplink_bytes
    );
    // unit accounting ~ gamma on maskable params (biases stay dense)
    let mm = manifest.model("lenet").unwrap();
    let maskable = mm.maskable_params() as f64 / mm.p as f64;
    let expected_unit = 0.2 * maskable + (1.0 - maskable);
    let per_upload = masked_out.ledger.uplink_units / (2.0 * 4.0);
    assert!(
        (per_upload - expected_unit).abs() < 0.02,
        "per-upload units {per_upload} vs expected {expected_unit}"
    );
}

/// Acceptance: streamed FedAvg over decoded wire payloads is bitwise
/// identical to the barrier aggregation, for every arrival order. Runs
/// without artifacts — the whole wire + aggregation plane is pure rust.
#[test]
fn streamed_fedavg_from_wire_payloads_is_bitwise_identical_to_barrier() {
    // Fixed seed: sparse masked-style updates, realistic FedAvg weights.
    let mut g = fedmask::util::prop::Gen::new(0xfed_2026);
    let p = 1_203;
    let k = 5;
    let mut dense_updates: Vec<Vec<f32>> = Vec::new();
    let mut weights: Vec<u32> = Vec::new();
    for _ in 0..k {
        let density = g.f32_in(0.1, 0.6);
        dense_updates.push(
            (0..p)
                .map(|_| {
                    if g.f32_in(0.0, 1.0) < density {
                        g.f32_in(-1.5, 1.5)
                    } else {
                        0.0
                    }
                })
                .collect(),
        );
        weights.push(g.usize_in(50, 800) as u32);
    }

    // The wire is the only carrier: encode every update, then aggregate
    // strictly from decoded payloads.
    let payloads: Vec<Vec<u8>> = dense_updates
        .iter()
        .zip(&weights)
        .enumerate()
        .map(|(c, (v, &w))| encode_update(c as u32, 1, w, v, Encoding::Auto))
        .collect();
    let decoded: Vec<_> = payloads.iter().map(|b| decode_update(b).unwrap()).collect();
    let densified: Vec<Vec<f32>> = decoded.iter().map(|u| u.to_dense()).collect();
    for (d, v) in densified.iter().zip(&dense_updates) {
        assert_eq!(d, v, "lossless codec must hand back the update");
    }
    let contribs: Vec<Contribution> = decoded
        .iter()
        .zip(&densified)
        .map(|(u, d)| Contribution {
            client: u.client as usize,
            params: d,
            n_samples: u.n_samples,
        })
        .collect();

    let barrier = weighted_mean(&contribs).unwrap();
    // every rotation + the reversal: arrival order must not move a bit
    let mut orders: Vec<Vec<usize>> = (0..k).map(|s| (0..k).map(|i| (i + s) % k).collect()).collect();
    orders.push((0..k).rev().collect());
    for order in orders {
        let mut agg = StreamingFedAvg::new(p);
        for &i in &order {
            agg.fold(contribs[i].clone()).unwrap();
        }
        let streamed = Box::new(agg).finish().unwrap();
        assert_eq!(
            streamed, barrier,
            "arrival order {order:?} changed the aggregate"
        );
    }

    // The sparse-native fold (wire bodies folded without densification —
    // the server's actual hot path) lands on exactly the same bits.
    let mut agg = StreamingFedAvg::new(p);
    for u in &decoded {
        match &u.body {
            DecodedBody::Sparse { indices, values } => agg
                .fold_sparse(SparseContribution {
                    client: u.client as usize,
                    p,
                    indices,
                    values,
                    n_samples: u.n_samples,
                })
                .unwrap(),
            DecodedBody::Dense(d) => agg
                .fold(Contribution {
                    client: u.client as usize,
                    params: d,
                    n_samples: u.n_samples,
                })
                .unwrap(),
        }
    }
    let sparse_native = Box::new(agg).finish().unwrap();
    assert_eq!(sparse_native, barrier, "sparse fold changed the aggregate");
}

#[test]
fn runs_are_deterministic_across_pool_widths() {
    let Some(manifest) = manifest() else { return };
    let run = |workers: usize| {
        let mut cfg = tiny_cfg("det");
        cfg.rounds = 2;
        cfg.workers = workers;
        cfg.masking = MaskPolicy::selective(0.5);
        Server::new(cfg, &manifest).unwrap().run().unwrap()
    };
    let a = run(1);
    let b = run(3);
    assert_eq!(a.final_params, b.final_params, "pool width must not change results");
    assert_eq!(a.ledger.uplink_bytes, b.ledger.uplink_bytes);
}

#[test]
fn availability_failures_shrink_cohorts_but_training_continues() {
    let Some(manifest) = manifest() else { return };
    let mut cfg = tiny_cfg("flaky");
    cfg.clients = 6;
    cfg.rounds = 3;
    cfg.ack_prob = 0.5;
    let outcome = Server::new(cfg, &manifest).unwrap().run().unwrap();
    // some rounds must have aggregated fewer than all clients
    assert!(outcome.recorder.rounds.iter().any(|r| r.clients < 6));
    // but every round aggregated at least one and produced finite params
    assert!(outcome.recorder.rounds.iter().all(|r| r.clients >= 1));
    assert!(outcome.final_params.iter().all(|v| v.is_finite()));
}
