//! Loopback socket transport integration tests.
//!
//! The acceptance bar: one federated round over real sockets (TCP and
//! UDS) must be **bitwise identical** to the in-process transport — same
//! aggregate, same byte accounting — and malformed peers must be rejected
//! with typed errors without disturbing the cohort.
//!
//! Real sockets are not available in every sandbox, so every test here is
//! gated on `FEDMASK_SOCKET_TESTS=1` (CI sets it; offline sandboxes skip
//! cleanly). The full-round tests additionally need the PJRT artifacts and
//! self-skip without them, exactly like `fl_integration.rs`.

use std::time::Duration;

use fedmask::config::experiment::{AggregatorKind, ExperimentConfig};
use fedmask::fl::aggregate::make_aggregator;
use fedmask::fl::aggregate::{Contribution, SparseContribution};
use fedmask::fl::masking::{MaskPolicy, MaskTarget};
use fedmask::fl::server::Server;
use fedmask::runtime::manifest::{LayerInfo, Manifest};
use fedmask::transport::codec::{decode_update, encode_update, DecodedBody, Encoding};
use fedmask::transport::frame::{frame_bytes, FRAME_HEADER_BYTES, FRAME_MAGIC, FRAME_VERSION};
use fedmask::transport::link::{Simulated, Transport, TransportKind, UploadSink};
use fedmask::transport::network::NetworkModel;
use fedmask::transport::socket::{send_payload, Loopback, WireAddr};
use fedmask::util::prop::Gen;

/// Socket tests only run when explicitly enabled (stock CI runners have
/// working localhost TCP + UDS; sealed sandboxes may not).
fn socket_tests_enabled() -> bool {
    match std::env::var("FEDMASK_SOCKET_TESTS") {
        Ok(v) if v == "1" || v == "true" => true,
        _ => {
            eprintln!("skipping socket test (set FEDMASK_SOCKET_TESTS=1 to enable)");
            false
        }
    }
}

fn manifest() -> Option<Manifest> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    match Manifest::load(&dir) {
        Ok(m) => Some(m),
        Err(e) => {
            eprintln!("skipping socket integration test (run `make artifacts`): {e}");
            None
        }
    }
}

fn one_layer(size: usize) -> Vec<LayerInfo> {
    vec![LayerInfo {
        name: "w".into(),
        shape: vec![size],
        offset: 0,
        size,
        masked: true,
    }]
}

/// Masked-style update: mostly zeros, a few non-zero coordinates.
fn masked_update(g: &mut Gen, p: usize, density: f32) -> Vec<f32> {
    (0..p)
        .map(|_| {
            if g.f32_in(0.0, 1.0) < density {
                g.f32_in(-1.5, 1.5)
            } else {
                0.0
            }
        })
        .collect()
}

/// Fold a set of encoded payloads (in the given order) into a finished
/// aggregate under the given mask target.
fn fold_payloads(
    payloads: &[Vec<u8>],
    target: MaskTarget,
    broadcast: &[f32],
    layers: &[LayerInfo],
) -> Vec<f32> {
    let mut agg = make_aggregator(AggregatorKind::FedAvg, target, broadcast, layers).unwrap();
    for bytes in payloads {
        let u = decode_update(bytes).unwrap();
        match &u.body {
            DecodedBody::Dense(v) => agg
                .fold(Contribution {
                    client: u.client as usize,
                    params: v,
                    n_samples: u.n_samples,
                })
                .unwrap(),
            DecodedBody::Sparse { indices, values } => agg
                .fold_sparse(SparseContribution {
                    client: u.client as usize,
                    p: u.p,
                    indices,
                    values,
                    n_samples: u.n_samples,
                })
                .unwrap(),
        }
    }
    agg.finish().unwrap()
}

/// Ship `payloads` through a bound loopback transport from client threads
/// in deliberately scrambled completion order; return them in arrival
/// order.
fn ship_through(server: &mut Loopback, payloads: &[Vec<u8>]) -> Vec<Vec<u8>> {
    server.set_timeout(Duration::from_secs(30));
    let addr = server.addr().clone();
    let handles: Vec<_> = payloads
        .iter()
        .enumerate()
        .map(|(i, p)| {
            let addr = addr.clone();
            let p = p.clone();
            let delay = Duration::from_millis(((payloads.len() - i) * 15) as u64);
            std::thread::spawn(move || {
                // reverse-staggered: client 0 lands last
                std::thread::sleep(delay);
                send_payload(&addr, &p).unwrap();
            })
        })
        .collect();
    let got: Vec<Vec<u8>> = (0..payloads.len()).map(|_| server.recv().unwrap()).collect();
    for h in handles {
        h.join().unwrap();
    }
    got
}

/// Payloads that crossed a real socket are bitwise identical to what was
/// sent, and the aggregate folded from them matches the direct in-process
/// fold exactly — for both mask targets, over TCP and UDS, with clients
/// completing out of order.
#[test]
fn loopback_payloads_and_aggregate_are_bitwise_identical_to_in_process() {
    if !socket_tests_enabled() {
        return;
    }
    let mut g = Gen::new(0x50cce7);
    let p = 409;
    let k = 6;
    let broadcast: Vec<f32> = (0..p).map(|_| g.f32_in(-1.0, 1.0)).collect();
    let layers = one_layer(p);
    let payloads: Vec<Vec<u8>> = (0..k)
        .map(|c| {
            let update = masked_update(&mut g, p, 0.15);
            // cycle the encodings so every wire tag family (f32 sparse,
            // delta+varint, q8, q4) crosses a real socket
            let enc = [
                Encoding::Auto,
                Encoding::AutoQ8,
                Encoding::SparseDelta,
                Encoding::AutoQ4,
            ][c % 4];
            encode_update(c as u32, 1, 100 + c as u32, &update, enc)
        })
        .collect();

    for kind in [TransportKind::Tcp, TransportKind::Uds] {
        let mut server = Loopback::bind(kind).unwrap();
        let received = ship_through(&mut server, &payloads);

        // the wire must hand back exactly the bytes that went in
        let mut sent_sorted = payloads.clone();
        sent_sorted.sort();
        let mut recv_sorted = received.clone();
        recv_sorted.sort();
        assert_eq!(recv_sorted, sent_sorted, "{kind:?}: payload bytes changed in flight");

        // and the streamed fold over socket arrivals matches the direct
        // in-process fold bitwise, under both mask targets
        for target in [MaskTarget::Delta, MaskTarget::Weights] {
            let direct = fold_payloads(&payloads, target, &broadcast, &layers);
            let via_wire = fold_payloads(&received, target, &broadcast, &layers);
            assert_eq!(via_wire, direct, "{kind:?}/{target:?}: aggregate moved");
        }
    }
}

/// Adversarial peers — bad magic, unsupported version, over-cap length,
/// truncated body / mid-frame disconnect — are dropped at their own
/// connection; the cohort's uploads still arrive intact.
#[test]
fn server_survives_malformed_peers_while_folding_the_cohort() {
    if !socket_tests_enabled() {
        return;
    }
    let mut g = Gen::new(0xbadbeef);
    let p = 211;
    let k = 4;
    let payloads: Vec<Vec<u8>> = (0..k)
        .map(|c| {
            let update = masked_update(&mut g, p, 0.2);
            encode_update(c as u32, 3, 50, &update, Encoding::Auto)
        })
        .collect();

    let mut server = Loopback::bind(TransportKind::Tcp).unwrap();
    server.set_timeout(Duration::from_secs(30));
    let WireAddr::Tcp(addr) = server.addr().clone() else {
        panic!("tcp bind returned non-tcp addr")
    };

    // malformed peer 1: garbage magic
    {
        use std::io::Write;
        let mut s = std::net::TcpStream::connect(addr).unwrap();
        s.write_all(&[0xde, 0xad, 0xbe, 0xef, 0, 0, 0, 0, 1, 2, 3]).unwrap();
    }
    // malformed peer 2: valid header, then disconnect mid-body
    {
        use std::io::Write;
        let mut header = vec![0u8; FRAME_HEADER_BYTES];
        header[..2].copy_from_slice(&FRAME_MAGIC.to_le_bytes());
        header[2] = FRAME_VERSION;
        header[4..8].copy_from_slice(&1000u32.to_le_bytes());
        let mut s = std::net::TcpStream::connect(addr).unwrap();
        s.write_all(&header).unwrap();
        s.write_all(&[7u8; 12]).unwrap();
        // dropped here: 988 promised bytes never arrive
    }
    // malformed peer 3: declared length over the cap
    {
        use std::io::Write;
        let mut header = vec![0u8; FRAME_HEADER_BYTES];
        header[..2].copy_from_slice(&FRAME_MAGIC.to_le_bytes());
        header[2] = FRAME_VERSION;
        header[4..8].copy_from_slice(&u32::MAX.to_le_bytes());
        let mut s = std::net::TcpStream::connect(addr).unwrap();
        s.write_all(&header).unwrap();
    }
    // malformed peer 4: wrong frame version
    {
        use std::io::Write;
        let mut framed = frame_bytes(b"future payload").unwrap();
        framed[2] = FRAME_VERSION + 9;
        let mut s = std::net::TcpStream::connect(addr).unwrap();
        s.write_all(&framed).unwrap();
    }

    // the real cohort uploads after/between the attacks
    let received = ship_through(&mut server, &payloads);
    let mut sent_sorted = payloads.clone();
    sent_sorted.sort();
    let mut recv_sorted = received;
    recv_sorted.sort();
    assert_eq!(recv_sorted, sent_sorted, "cohort payloads lost to a malformed peer");

    // and nothing extra ever surfaces: the next recv times out instead of
    // yielding attacker bytes
    server.set_timeout(Duration::from_millis(300));
    assert!(server.recv().is_err(), "malformed peer bytes leaked into the round");
}

/// `Simulated` over a real socket orders deliveries by virtual upload
/// time, not by socket arrival order.
#[test]
fn simulated_over_loopback_orders_completions_by_upload_time() {
    if !socket_tests_enabled() {
        return;
    }
    let network = NetworkModel {
        client_bw: 1e6,
        server_bw: 1e9,
        latency_s: 0.01,
    };
    let inner = Loopback::bind(TransportKind::Tcp).unwrap();
    let mut t = Simulated::new(Box::new(inner), network.clone());
    let sink = t.sink();
    t.begin_round(3);
    // send big-to-small so socket arrival order opposes upload-time order
    for bytes in [9000usize, 2500, 40] {
        sink.send(vec![1u8; bytes]).unwrap();
    }
    let sizes: Vec<usize> = (0..3).map(|_| t.recv().unwrap().len()).collect();
    assert_eq!(sizes, vec![40, 2500, 9000], "delivery order must follow upload_time");
    assert!(network.upload_time(40) < network.upload_time(9000));
}

/// Acceptance: a full federated round over real TCP and UDS sockets —
/// PJRT training, masking, encode, frame, kernel socket, decode, fold —
/// produces a `RoundRecord` stream and final aggregate bitwise identical
/// to the in-process transport, for both mask targets, with a pool wide
/// enough that clients complete out of order.
#[test]
fn full_round_over_sockets_is_bitwise_identical_to_in_process() {
    if !socket_tests_enabled() {
        return;
    }
    let Some(manifest) = manifest() else { return };

    let run = |transport: TransportKind, target: MaskTarget| {
        let mut cfg = ExperimentConfig::defaults("lenet").unwrap();
        cfg.label = format!("wire-{}", transport.as_str());
        cfg.clients = 4;
        cfg.rounds = 2;
        cfg.n_train = 1_024;
        cfg.n_test = 512;
        cfg.eval_max_chunks = 1;
        cfg.workers = 3; // >1 worker: completion order is scheduler-driven
        cfg.seed = 7;
        cfg.masking = MaskPolicy::selective(0.3);
        cfg.mask_target = target;
        cfg.transport = transport;
        Server::new(cfg, &manifest).unwrap().run().unwrap()
    };

    for target in [MaskTarget::Delta, MaskTarget::Weights] {
        let reference = run(TransportKind::InProcess, target);
        for kind in [TransportKind::Tcp, TransportKind::Uds] {
            let socketed = run(kind, target);
            assert_eq!(
                socketed.final_params, reference.final_params,
                "{kind:?}/{target:?}: socket transport moved the aggregate"
            );
            assert_eq!(socketed.recorder.rounds.len(), reference.recorder.rounds.len());
            for (a, b) in socketed.recorder.rounds.iter().zip(&reference.recorder.rounds) {
                assert_eq!(a.round, b.round);
                assert_eq!(a.clients, b.clients, "{kind:?}/{target:?}");
                assert_eq!(a.uplink_bytes, b.uplink_bytes, "{kind:?}/{target:?}");
                assert_eq!(a.downlink_bytes, b.downlink_bytes, "{kind:?}/{target:?}");
                assert_eq!(
                    a.uplink_units.to_bits(),
                    b.uplink_units.to_bits(),
                    "{kind:?}/{target:?}"
                );
                assert_eq!(
                    a.train_loss.to_bits(),
                    b.train_loss.to_bits(),
                    "{kind:?}/{target:?}"
                );
                assert_eq!(
                    a.test_accuracy.to_bits(),
                    b.test_accuracy.to_bits(),
                    "{kind:?}/{target:?}"
                );
                assert_eq!(
                    a.virtual_time_s.to_bits(),
                    b.virtual_time_s.to_bits(),
                    "{kind:?}/{target:?}"
                );
            }
            assert_eq!(socketed.ledger.uplink_bytes, reference.ledger.uplink_bytes);
            assert_eq!(socketed.ledger.messages, reference.ledger.messages);
        }
    }
}

/// The in-process kind has no socket to bind — typed error, not a panic.
#[test]
fn binding_the_in_process_kind_is_a_typed_error() {
    assert!(Loopback::bind(TransportKind::InProcess).is_err());
}
