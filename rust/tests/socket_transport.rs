//! Loopback socket transport integration tests: full-duplex authenticated
//! sessions.
//!
//! The acceptance bar: one federated round over real sockets (TCP and
//! UDS) — **downlink broadcast and uploads both on the wire** — must be
//! bitwise identical to the in-process transport (same aggregate, same
//! byte accounting, for both mask targets across all six encodings), and
//! a spoofed upload with a missing/wrong session token must be rejected
//! before decode with the cohort surviving.
//!
//! Real sockets are not available in every sandbox, so every test here is
//! gated on `FEDMASK_SOCKET_TESTS=1` (CI sets it; offline sandboxes skip
//! cleanly). The full-round tests additionally need the PJRT artifacts and
//! self-skip without them, exactly like `fl_integration.rs`; the
//! engine-free `RoundDriver` cycles below need no artifacts at all.

use std::io::Read as _;
use std::sync::mpsc::channel;
use std::sync::Arc;
use std::time::Duration;

use fedmask::config::experiment::{AggregatorKind, ExperimentConfig};
use fedmask::fl::aggregate::{make_aggregator, Contribution, SparseContribution};
use fedmask::fl::chaos::Scenario;
use fedmask::fl::client::receive_broadcast;
use fedmask::fl::driver::{JobMeta, RoundDriver};
use fedmask::fl::masking::{MaskPolicy, MaskTarget};
use fedmask::fl::server::Server;
use fedmask::runtime::manifest::{LayerInfo, Manifest};
use fedmask::sim::availability::AvailabilityModel;
use fedmask::transport::codec::{decode_update, encode_update, peek_client, DecodedBody, Encoding};
use fedmask::transport::link::{Simulated, Transport, TransportKind};
use fedmask::transport::network::NetworkModel;
use fedmask::transport::socket::{ClientConn, Loopback, ServerTuning, WireAddr};
use fedmask::util::prop::Gen;

/// Socket tests only run when explicitly enabled (stock CI runners have
/// working localhost TCP + UDS; sealed sandboxes may not).
fn socket_tests_enabled() -> bool {
    match std::env::var("FEDMASK_SOCKET_TESTS") {
        Ok(v) if v == "1" || v == "true" => true,
        _ => {
            eprintln!("skipping socket test (set FEDMASK_SOCKET_TESTS=1 to enable)");
            false
        }
    }
}

fn manifest() -> Option<Manifest> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    match Manifest::load(&dir) {
        Ok(m) => Some(m),
        Err(e) => {
            eprintln!("skipping socket integration test (run `make artifacts`): {e}");
            None
        }
    }
}

fn one_layer(size: usize) -> Vec<LayerInfo> {
    vec![LayerInfo {
        name: "w".into(),
        shape: vec![size],
        offset: 0,
        size,
        masked: true,
    }]
}

/// Masked-style update: mostly zeros, a few non-zero coordinates.
fn masked_update(g: &mut Gen, p: usize, density: f32) -> Vec<f32> {
    (0..p)
        .map(|_| {
            if g.f32_in(0.0, 1.0) < density {
                g.f32_in(-1.5, 1.5)
            } else {
                0.0
            }
        })
        .collect()
}

/// Fold a set of encoded payloads (in the given order) into a finished
/// aggregate under the given mask target.
fn fold_payloads(
    payloads: &[Vec<u8>],
    target: MaskTarget,
    broadcast: &[f32],
    layers: &[LayerInfo],
) -> Vec<f32> {
    let mut agg = make_aggregator(AggregatorKind::FedAvg, target, broadcast, layers).unwrap();
    for bytes in payloads {
        let u = decode_update(bytes).unwrap();
        match &u.body {
            DecodedBody::Dense(v) => agg
                .fold(Contribution {
                    client: u.client as usize,
                    params: v,
                    n_samples: u.n_samples,
                })
                .unwrap(),
            DecodedBody::Sparse { indices, values } => agg
                .fold_sparse(SparseContribution {
                    client: u.client as usize,
                    p: u.p,
                    indices,
                    values,
                    n_samples: u.n_samples,
                })
                .unwrap(),
        }
    }
    agg.finish().unwrap()
}

/// Register the payloads' senders, then ship each payload through its
/// client's persistent authenticated session from client threads in
/// deliberately scrambled completion order; return them in arrival order.
fn ship_through(server: &mut Loopback, payloads: &[Vec<u8>]) -> Vec<Vec<u8>> {
    server.set_timeout(Duration::from_secs(30));
    let clients: Vec<u32> = payloads.iter().map(|p| peek_client(p).unwrap()).collect();
    server.register_clients(&clients).unwrap();
    let sink = server.sink();
    let handles: Vec<_> = payloads
        .iter()
        .enumerate()
        .map(|(i, p)| {
            let sink = Arc::clone(&sink);
            let p = p.clone();
            let delay = Duration::from_millis(((payloads.len() - i) * 15) as u64);
            std::thread::spawn(move || {
                // reverse-staggered: client 0 lands last
                std::thread::sleep(delay);
                sink.send(p).unwrap();
            })
        })
        .collect();
    let got: Vec<Vec<u8>> = (0..payloads.len()).map(|_| server.recv().unwrap()).collect();
    for h in handles {
        h.join().unwrap();
    }
    got
}

/// Payloads that crossed a real socket (through the per-client sessions)
/// are bitwise identical to what was sent, and the aggregate folded from
/// them matches the direct in-process fold exactly — for both mask
/// targets, over TCP and UDS, with clients completing out of order.
#[test]
fn loopback_payloads_and_aggregate_are_bitwise_identical_to_in_process() {
    if !socket_tests_enabled() {
        return;
    }
    let mut g = Gen::new(0x50cce7);
    let p = 409;
    let k = 6;
    let broadcast: Vec<f32> = (0..p).map(|_| g.f32_in(-1.0, 1.0)).collect();
    let layers = one_layer(p);
    let payloads: Vec<Vec<u8>> = (0..k)
        .map(|c| {
            let update = masked_update(&mut g, p, 0.15);
            // cycle the encodings so every wire tag family (f32 sparse,
            // delta+varint, q8, q4) crosses a real socket
            let enc = [
                Encoding::Auto,
                Encoding::AutoQ8,
                Encoding::SparseDelta,
                Encoding::AutoQ4,
            ][c % 4];
            encode_update(c as u32, 1, 100 + c as u32, &update, enc)
        })
        .collect();

    for kind in [TransportKind::Tcp, TransportKind::Uds] {
        let mut server = Loopback::bind(kind).unwrap();
        let received = ship_through(&mut server, &payloads);

        // the wire must hand back exactly the bytes that went in
        let mut sent_sorted = payloads.clone();
        sent_sorted.sort();
        let mut recv_sorted = received.clone();
        recv_sorted.sort();
        assert_eq!(recv_sorted, sent_sorted, "{kind:?}: payload bytes changed in flight");

        // and the streamed fold over socket arrivals matches the direct
        // in-process fold bitwise, under both mask targets
        for target in [MaskTarget::Delta, MaskTarget::Weights] {
            let direct = fold_payloads(&payloads, target, &broadcast, &layers);
            let via_wire = fold_payloads(&received, target, &broadcast, &layers);
            assert_eq!(via_wire, direct, "{kind:?}/{target:?}: aggregate moved");
        }
    }
}

/// Adversarial peers — bad magic, mid-frame disconnect, over-cap length,
/// unsupported versions — are dropped at their own connection while the
/// cohort's authenticated uploads arrive intact. The attacks themselves
/// live in the `malformed-peers` scenario registry
/// (`fl::chaos::WireAdversary`), so `fedmask run --scenario
/// malformed-peers` and this test exercise byte-identical adversaries.
#[test]
fn malformed_peers_scenario_is_absorbed_while_the_cohort_folds() {
    if !socket_tests_enabled() {
        return;
    }
    let mut g = Gen::new(0xbadbeef);
    let p = 211;
    let k = 4;
    let payloads: Vec<Vec<u8>> = (0..k)
        .map(|c| {
            let update = masked_update(&mut g, p, 0.2);
            encode_update(c as u32, 3, 50, &update, Encoding::Auto)
        })
        .collect();

    let scenario = Scenario::named("malformed-peers").unwrap();
    assert!(!scenario.wire_adversaries.is_empty(), "registry lost its adversaries");

    let mut server = Loopback::bind(TransportKind::Tcp).unwrap();
    server.set_timeout(Duration::from_secs(30));
    for adv in &scenario.wire_adversaries {
        adv.launch(&server, 0, 1, 3, p).unwrap();
    }

    // the real cohort uploads after the attacks
    let received = ship_through(&mut server, &payloads);
    let mut sent_sorted = payloads.clone();
    sent_sorted.sort();
    let mut recv_sorted = received;
    recv_sorted.sort();
    assert_eq!(recv_sorted, sent_sorted, "cohort payloads lost to a malformed peer");

    // and nothing extra ever surfaces: the next recv times out instead of
    // yielding attacker bytes
    server.set_timeout(Duration::from_millis(300));
    assert!(server.recv().is_err(), "malformed peer bytes leaked into the round");
}

/// The auth regressions, registry-driven: every `spoofed-tokens`
/// adversary — the token-less and guessed-token upload spoofs (the
/// pre-auth-refactor attack), a registration for an unknown id, a
/// re-registration of a live id, and a cross-client upload laundered
/// through a *valid* session — is rejected before the round, on both
/// socket families, with the genuine client's upload still folding.
#[test]
fn spoofed_tokens_scenario_is_rejected_before_the_round() {
    if !socket_tests_enabled() {
        return;
    }
    let p = 64;
    let round = 2u32;
    let mut g = Gen::new(0x5f00f);
    let genuine = encode_update(0, round, 40, &masked_update(&mut g, p, 0.3), Encoding::Auto);

    let scenario = Scenario::named("spoofed-tokens").unwrap();
    assert!(!scenario.wire_adversaries.is_empty(), "registry lost its adversaries");

    for kind in [TransportKind::Tcp, TransportKind::Uds] {
        let mut server = Loopback::bind(kind).unwrap();
        server.set_timeout(Duration::from_secs(30));
        server.register_clients(&[0, 1]).unwrap();

        // every adversary impersonates client 0; the cross-client attack
        // launders through client 1's live session
        for adv in &scenario.wire_adversaries {
            adv.launch(&server, 0, 1, round, p).unwrap();
        }

        // the genuine client 0 upload goes through its own session
        server.sink().send(genuine.clone()).unwrap();
        assert_eq!(server.recv().unwrap(), genuine, "{kind:?}: genuine upload must survive");

        // nothing else ever surfaces — every spoof path died pre-decode
        server.set_timeout(Duration::from_millis(300));
        assert!(server.recv().is_err(), "{kind:?}: a spoofed payload leaked into the round");
    }
}

/// `Simulated` over a real socket orders deliveries by virtual upload
/// time, not by socket arrival order.
#[test]
fn simulated_over_loopback_orders_completions_by_upload_time() {
    if !socket_tests_enabled() {
        return;
    }
    let network = NetworkModel {
        client_bw: 1e6,
        server_bw: 1e9,
        latency_s: 0.01,
    };
    let inner = Loopback::bind(TransportKind::Tcp).unwrap();
    let mut t = Simulated::new(Box::new(inner), network.clone());
    t.register_clients(&[0, 1, 2]).unwrap();
    let sink = t.sink();
    t.begin_round(3);
    // dense payloads of sharply different sizes; send big-to-small so
    // socket arrival order opposes upload-time order
    let sizes_p = [3000usize, 800, 10];
    let payloads: Vec<Vec<u8>> = sizes_p
        .iter()
        .enumerate()
        .map(|(c, &pp)| encode_update(c as u32, 1, 1, &vec![1.0f32; pp], Encoding::Dense))
        .collect();
    for p in &payloads {
        sink.send(p.clone()).unwrap();
    }
    let got: Vec<usize> = (0..3).map(|_| t.recv().unwrap().len()).collect();
    let mut want: Vec<usize> = payloads.iter().map(Vec::len).collect();
    want.sort_unstable();
    assert_eq!(got, want, "delivery order must follow upload_time (ascending size)");
    assert!(network.upload_time(want[0]) < network.upload_time(want[2]));
}

// ---------------------------------------------------------------------
// Engine-free full-duplex RoundDriver cycles over real sockets
// ---------------------------------------------------------------------

fn always_on(seed: u64) -> AvailabilityModel {
    AvailabilityModel::new(1.0, 0.0, seed)
}

/// Deterministic fake update derived from the broadcast the client
/// decoded off the wire — any downlink discrepancy changes the aggregate.
fn fake_update(global: &[f32], client: usize) -> Vec<f32> {
    global
        .iter()
        .enumerate()
        .map(|(j, g)| {
            if j % 4 == client % 4 {
                g * 0.5 + (client as f32 + 1.0) * 0.125
            } else {
                0.0
            }
        })
        .collect()
}

/// Two full sample → broadcast → collect → finalize cycles (the second
/// exercising the delta-downlink reconstruction) with fake clients on
/// threads pulling the broadcast off the transport's downlink half and
/// uploading through their sessions. Returns everything that must be
/// transport-invariant.
#[allow(clippy::type_complexity)]
fn fake_two_rounds(
    transport: TransportKind,
    enc: Encoding,
    target: MaskTarget,
    p: usize,
) -> (Vec<f32>, Vec<f32>, Vec<f32>, Vec<f32>, u64, u64, f64) {
    let mut cfg = ExperimentConfig::defaults("lenet").unwrap();
    cfg.clients = 4;
    cfg.transport = transport;
    cfg.encoding = enc;
    cfg.downlink_delta = true;
    let cfg = Arc::new(cfg);
    let mut driver = RoundDriver::new(Arc::clone(&cfg), p).unwrap();
    driver.set_upload_timeout(Duration::from_secs(30));
    let layers = one_layer(p);

    let mut run_round = |t: usize, params: &Arc<Vec<f32>>| -> (Vec<f32>, Vec<f32>, f64) {
        let cohort = driver.sample(&always_on(7), t);
        assert_eq!(cohort.selected.len(), 4, "static C=1 selects everyone");
        let wire = driver.broadcast(params, &cohort).unwrap();
        let sink = driver.sink();
        let downlink = driver.downlink();
        let (tx, results) = channel::<(usize, fedmask::Result<JobMeta>)>();
        let handles: Vec<_> = cohort
            .selected
            .iter()
            .enumerate()
            .map(|(i, &c)| {
                let sink = Arc::clone(&sink);
                let downlink = Arc::clone(&downlink);
                let reference = wire.references[i].clone();
                let tx = tx.clone();
                std::thread::spawn(move || {
                    let global = receive_broadcast(
                        downlink.as_ref(),
                        c as u32,
                        t as u32,
                        reference.as_deref().map(Vec::as_slice),
                        Duration::from_secs(30),
                    )
                    .unwrap();
                    let update = fake_update(&global, c);
                    let nnz = update.iter().filter(|v| **v != 0.0).count();
                    let payload =
                        encode_update(c as u32, t as u32, 10 + c as u32, &update, enc);
                    let bytes = payload.len();
                    sink.send(payload).unwrap();
                    tx.send((i, Ok((0.25, nnz, bytes)))).unwrap();
                })
            })
            .collect();
        drop(tx);
        let mut agg =
            make_aggregator(AggregatorKind::FedAvg, target, &wire.params, &layers).unwrap();
        let collected = driver.collect(&cohort, agg.as_mut(), &results).unwrap();
        for h in handles {
            h.join().unwrap();
        }
        driver.finalize(&collected);
        (agg.finish().unwrap(), (*wire.params).clone(), wire.recon_err)
    };

    let params0: Arc<Vec<f32>> = Arc::new((0..p).map(|j| (j as f32 * 0.37).sin()).collect());
    let (agg1, bcast1, _) = run_round(1, &params0);
    let params1 = Arc::new(agg1.clone());
    let (agg2, bcast2, recon2) = run_round(2, &params1);
    let ledger = driver.ledger();
    (agg1, bcast1, agg2, bcast2, ledger.downlink_bytes, ledger.uplink_bytes, recon2)
}

/// Acceptance (engine-free): two full-duplex rounds over persistent TCP
/// and UDS sessions — broadcast down the wire, uploads back up, delta
/// downlink on the second round — are **bitwise identical** to the
/// in-process transport, for every encoding and both mask targets.
#[test]
fn full_duplex_driver_rounds_over_sockets_match_in_process_bitwise() {
    if !socket_tests_enabled() {
        return;
    }
    let p = 32;
    for &enc in Encoding::ALL {
        for target in [MaskTarget::Delta, MaskTarget::Weights] {
            let reference = fake_two_rounds(TransportKind::InProcess, enc, target, p);
            for kind in [TransportKind::Tcp, TransportKind::Uds] {
                let got = fake_two_rounds(kind, enc, target, p);
                assert_eq!(got.0, reference.0, "{enc:?}/{target:?}/{kind:?}: round-1 aggregate");
                assert_eq!(got.1, reference.1, "{enc:?}/{target:?}/{kind:?}: round-1 broadcast");
                assert_eq!(got.2, reference.2, "{enc:?}/{target:?}/{kind:?}: round-2 aggregate");
                assert_eq!(got.3, reference.3, "{enc:?}/{target:?}/{kind:?}: round-2 broadcast");
                assert_eq!(got.4, reference.4, "{enc:?}/{target:?}/{kind:?}: downlink bytes");
                assert_eq!(got.5, reference.5, "{enc:?}/{target:?}/{kind:?}: uplink bytes");
                assert_eq!(
                    got.6.to_bits(),
                    reference.6.to_bits(),
                    "{enc:?}/{target:?}/{kind:?}: recon err"
                );
            }
        }
    }
}

/// Acceptance (PJRT): a full federated round over real TCP and UDS
/// sockets — training, masking, encode, frame, kernel socket in **both
/// directions**, decode, fold — produces a `RoundRecord` stream and final
/// aggregate bitwise identical to the in-process transport, for both mask
/// targets and both downlink modes, with a pool wide enough that clients
/// complete out of order.
#[test]
fn full_round_over_sockets_is_bitwise_identical_to_in_process() {
    if !socket_tests_enabled() {
        return;
    }
    let Some(manifest) = manifest() else { return };

    let run = |transport: TransportKind, target: MaskTarget, downlink_delta: bool| {
        let mut cfg = ExperimentConfig::defaults("lenet").unwrap();
        cfg.label = format!("wire-{}", transport.as_str());
        cfg.clients = 4;
        cfg.rounds = 2;
        cfg.n_train = 1_024;
        cfg.n_test = 512;
        cfg.eval_max_chunks = 1;
        cfg.workers = 3; // >1 worker: completion order is scheduler-driven
        cfg.seed = 7;
        cfg.masking = MaskPolicy::selective(0.3);
        cfg.mask_target = target;
        cfg.transport = transport;
        cfg.downlink_delta = downlink_delta;
        Server::new(cfg, &manifest).unwrap().run().unwrap()
    };

    for target in [MaskTarget::Delta, MaskTarget::Weights] {
        for downlink_delta in [false, true] {
            let reference = run(TransportKind::InProcess, target, downlink_delta);
            for kind in [TransportKind::Tcp, TransportKind::Uds] {
                let socketed = run(kind, target, downlink_delta);
                assert_eq!(
                    socketed.final_params, reference.final_params,
                    "{kind:?}/{target:?}/dd={downlink_delta}: socket transport moved the aggregate"
                );
                assert_eq!(socketed.recorder.rounds.len(), reference.recorder.rounds.len());
                for (a, b) in socketed.recorder.rounds.iter().zip(&reference.recorder.rounds) {
                    assert_eq!(a.round, b.round);
                    assert_eq!(a.clients, b.clients, "{kind:?}/{target:?}");
                    assert_eq!(a.uplink_bytes, b.uplink_bytes, "{kind:?}/{target:?}");
                    assert_eq!(a.downlink_bytes, b.downlink_bytes, "{kind:?}/{target:?}");
                    assert_eq!(
                        a.uplink_units.to_bits(),
                        b.uplink_units.to_bits(),
                        "{kind:?}/{target:?}"
                    );
                    assert_eq!(
                        a.train_loss.to_bits(),
                        b.train_loss.to_bits(),
                        "{kind:?}/{target:?}"
                    );
                    assert_eq!(
                        a.test_accuracy.to_bits(),
                        b.test_accuracy.to_bits(),
                        "{kind:?}/{target:?}"
                    );
                    assert_eq!(
                        a.downlink_recon_err.to_bits(),
                        b.downlink_recon_err.to_bits(),
                        "{kind:?}/{target:?}/dd={downlink_delta}"
                    );
                    assert_eq!(
                        a.virtual_time_s.to_bits(),
                        b.virtual_time_s.to_bits(),
                        "{kind:?}/{target:?}"
                    );
                }
                assert_eq!(socketed.ledger.uplink_bytes, reference.ledger.uplink_bytes);
                assert_eq!(socketed.ledger.messages, reference.ledger.messages);
            }
        }
    }
}

// ---------------------------------------------------------------------
// Reactor admission control and pre-auth reaping
// ---------------------------------------------------------------------

/// Admission control: once `max_conns` live connections exist, further
/// accepts are refused before any frame is read — the over-cap peer sees
/// a clean close (typed handshake error client-side), never a hang — and
/// the established cohort keeps working. A departing connection frees
/// its slot for the next peer.
#[test]
fn over_cap_connections_are_refused_cleanly_and_existing_sessions_survive() {
    if !socket_tests_enabled() {
        return;
    }
    let tuning = ServerTuning { max_conns: 2, ..ServerTuning::default() };
    let mut server = Loopback::bind_tcp_with(tuning).unwrap();
    server.set_timeout(Duration::from_secs(30));
    server.allow_clients(&[0, 1, 2]).unwrap();
    let addr = server.addr().clone();

    let conn0 = ClientConn::connect(&addr, 0).unwrap();
    let _conn1 = ClientConn::connect(&addr, 1).unwrap();

    // cap reached: client 2 is *registered* but cannot be admitted; the
    // refusal surfaces as a clean close during its handshake
    let err = ClientConn::connect(&addr, 2).unwrap_err();
    assert!(
        err.to_string().contains("refused") || err.to_string().contains("closed"),
        "{err}"
    );

    // the refusals never disturb established sessions
    let payload = encode_update(0, 1, 5, &vec![1.0f32; 16], Encoding::Dense);
    conn0.upload(&payload).unwrap();
    assert_eq!(server.recv().unwrap(), payload);

    // a departing connection frees its slot; the reactor notices the
    // close on its next scan, so retry briefly rather than racing it
    drop(conn0);
    let mut admitted = None;
    for _ in 0..150 {
        match ClientConn::connect(&addr, 2) {
            Ok(c) => {
                admitted = Some(c);
                break;
            }
            Err(_) => std::thread::sleep(Duration::from_millis(20)),
        }
    }
    let conn2 = admitted.expect("admission slot never freed after a disconnect");
    let payload2 = encode_update(2, 1, 5, &vec![2.0f32; 16], Encoding::Dense);
    conn2.upload(&payload2).unwrap();
    assert_eq!(server.recv().unwrap(), payload2);
}

/// Pre-auth reaping: a peer that connects and never says `hello` is torn
/// down once `handshake_timeout` passes — its socket is closed
/// server-side and its admission slot freed — while a genuine client
/// registering afterwards is admitted and authenticated normally.
#[test]
fn idle_preauth_connections_are_reaped_after_the_handshake_timeout() {
    if !socket_tests_enabled() {
        return;
    }
    let tuning = ServerTuning {
        max_conns: 1,
        handshake_timeout: Duration::from_millis(200),
        ..ServerTuning::default()
    };
    let mut server = Loopback::bind_tcp_with(tuning).unwrap();
    server.set_timeout(Duration::from_secs(30));
    server.allow_clients(&[0]).unwrap();
    let WireAddr::Tcp(addr) = server.addr().clone() else { unreachable!() };

    // a mute peer occupies the only slot...
    let mute = std::net::TcpStream::connect(addr).unwrap();
    // ...so the genuine client is refused while the slot is held
    let err = ClientConn::connect(server.addr(), 0).unwrap_err();
    assert!(
        err.to_string().contains("refused") || err.to_string().contains("closed"),
        "{err}"
    );

    // past the deadline the reactor reaps the mute peer: its socket is
    // closed server-side (EOF or reset — either proves the teardown)
    std::thread::sleep(Duration::from_millis(500));
    mute.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
    let mut buf = [0u8; 8];
    match (&mute).read(&mut buf) {
        Ok(0) | Err(_) => {}
        Ok(n) => panic!("reaped pre-auth connection yielded {n} bytes"),
    }

    // the freed slot admits the genuine client, whose session works
    let conn = ClientConn::connect(server.addr(), 0).unwrap();
    let payload = encode_update(0, 1, 9, &vec![3.0f32; 8], Encoding::Dense);
    conn.upload(&payload).unwrap();
    assert_eq!(server.recv().unwrap(), payload);
}

/// The in-process kind has no socket to bind — typed error, not a panic.
#[test]
fn binding_the_in_process_kind_is_a_typed_error() {
    assert!(Loopback::bind(TransportKind::InProcess).is_err());
}
