"""L1 kernel tests: Pallas selective-mask vs. the pure-jnp oracle.

Hypothesis sweeps shapes/rates; fixed cases pin the edge behaviour the
coordinator relies on (gamma=1 passthrough, tiny segments, layered masking).
"""

import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import HealthCheck, given, settings
from hypothesis import strategies as st

from compile.kernels.ref import (
    random_mask_ref,
    selective_mask_ref,
    selective_mask_threshold_ref,
)
from compile.kernels.selective_mask import selective_mask, selective_mask_layered

_jit_mask = jax.jit(lambda wn, wo, g: selective_mask(wn, wo, g))


def _rand(p, seed):
    rng = np.random.default_rng(seed)
    return (
        jnp.asarray(rng.normal(size=p).astype(np.float32)),
        jnp.asarray(rng.normal(size=p).astype(np.float32)),
    )


@settings(max_examples=30, deadline=None, suppress_health_check=[HealthCheck.too_slow])
@given(
    p=st.integers(min_value=1, max_value=9000),
    gamma=st.floats(min_value=0.05, max_value=1.0, allow_nan=False),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_kernel_matches_oracle(p, gamma, seed):
    wn, wo = _rand(p, seed)
    out = np.asarray(_jit_mask(wn, wo, jnp.float32(gamma)))
    ref = np.asarray(selective_mask_ref(wn, wo, gamma))
    k = round(gamma * p)
    kept = int((out != 0).sum())
    # continuous data -> ties measure-zero; bisection resolves below f32 eps
    assert abs(kept - k) <= max(1, int(0.002 * p))
    # kept positions must agree with the oracle except at the tie boundary
    disagree = int(((out != 0) != (ref != 0)).sum())
    assert disagree <= max(1, int(0.002 * p))
    # kept entries are w_new verbatim; dropped entries are exactly zero
    np.testing.assert_array_equal(out[out != 0], np.asarray(wn)[out != 0])


@settings(max_examples=20, deadline=None)
@given(
    p=st.integers(min_value=32, max_value=4096),
    gamma=st.floats(min_value=0.05, max_value=0.95),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_threshold_dominance(p, gamma, seed):
    """Every kept |delta| >= every dropped |delta| (the top-k property)."""
    wn, wo = _rand(p, seed)
    out = np.asarray(_jit_mask(wn, wo, jnp.float32(gamma)))
    d = np.abs(np.asarray(wn) - np.asarray(wo))
    kept, dropped = d[out != 0], d[out == 0]
    if kept.size and dropped.size:
        assert kept.min() >= dropped.max() - 1e-7


def test_gamma_one_keeps_everything():
    wn, wo = _rand(513, 7)
    out = np.asarray(_jit_mask(wn, wo, jnp.float32(1.0)))
    np.testing.assert_array_equal(out, np.asarray(wn))


def test_block_size_invariance():
    """Result is independent of the VMEM block tiling."""
    wn, wo = _rand(5000, 3)
    outs = [
        np.asarray(jax.jit(functools.partial(selective_mask, block=b))(wn, wo, jnp.float32(0.3)))
        for b in (256, 1024, 4096)
    ]
    np.testing.assert_array_equal(outs[0], outs[1])
    np.testing.assert_array_equal(outs[0], outs[2])


def test_identical_weights_zero_delta():
    """w_new == w_old -> all deltas zero; kept set is the zero-tie set and
    the masked output must still be a subset of w_new values."""
    wn, _ = _rand(1000, 5)
    out = np.asarray(_jit_mask(wn, wn, jnp.float32(0.5)))
    # tau -> 0 with all-tied deltas; everything is kept (count >= k invariant)
    np.testing.assert_array_equal(out, np.asarray(wn))


@pytest.mark.parametrize("gamma", [0.1, 0.5, 0.9])
def test_layered_masks_each_segment_independently(gamma):
    wn, wo = _rand(3000, 11)
    segments = [(0, 1000, True), (1000, 40, False), (1040, 1960, True)]
    out = np.asarray(
        jax.jit(lambda a, b, g: selective_mask_layered(a, b, g, segments))(
            wn, wo, jnp.float32(gamma)
        )
    )
    # unmasked segment passes through verbatim
    np.testing.assert_array_equal(out[1000:1040], np.asarray(wn)[1000:1040])
    for off, size in ((0, 1000), (1040, 1960)):
        kept = int((out[off : off + size] != 0).sum())
        assert abs(kept - round(gamma * size)) <= max(1, int(0.01 * size))


def test_layered_equals_flat_per_segment():
    wn, wo = _rand(2048, 13)
    segments = [(0, 2048, True)]
    a = np.asarray(
        jax.jit(lambda x, y, g: selective_mask_layered(x, y, g, segments))(wn, wo, jnp.float32(0.4))
    )
    b = np.asarray(_jit_mask(wn, wo, jnp.float32(0.4)))
    np.testing.assert_array_equal(a, b)


def test_threshold_ref_consistency():
    wn, wo = _rand(4096, 17)
    tau = float(selective_mask_threshold_ref(wn, wo, 0.25))
    d = np.abs(np.asarray(wn) - np.asarray(wo))
    assert (d >= tau).sum() == round(0.25 * 4096)


def test_random_mask_ref_rate():
    key = jax.random.PRNGKey(0)
    w = jnp.ones(20000)
    out = np.asarray(random_mask_ref(key, w, 0.3))
    frac = (out != 0).mean()
    assert abs(frac - 0.3) < 0.02
