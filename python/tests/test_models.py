"""L2 model tests: shapes, init, gradient flow, learnability, determinism."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.models import REGISTRY, build_fns
from compile.models.common import pack, unpack


def _image_batch(md, seed=0):
    rng = np.random.default_rng(seed)
    tmpl = rng.normal(size=(10, *md.x_elem_shape)).astype(np.float32)
    ys = rng.integers(0, 10, size=(md.nb_train, md.batch)).astype(np.int32)
    xs = (tmpl[ys] + 0.3 * rng.normal(size=(md.nb_train, md.batch, *md.x_elem_shape))).astype(
        np.float32
    )
    return jnp.asarray(xs), jnp.asarray(ys)


def _lm_batch(md, seed=0):
    rng = np.random.default_rng(seed)
    seq = md.x_elem_shape[0]
    toks = rng.integers(0, 50, size=(md.nb_train, md.batch, seq + 1)).astype(np.int32)
    return jnp.asarray(toks[..., :-1]), jnp.asarray(toks[..., 1:])


def _batches(md, seed=0):
    return _lm_batch(md, seed) if md.task == "lm" else _image_batch(md, seed)


@pytest.fixture(scope="module", params=list(REGISTRY))
def model(request):
    md = REGISTRY[request.param]
    return md, build_fns(md)


def test_param_count_matches_layer_table(model):
    md, _ = model
    table = md.layer_table()
    assert sum(t["size"] for t in table) == md.param_count
    # offsets are contiguous and ordered
    offset = 0
    for t in table:
        assert t["offset"] == offset
        offset += t["size"]


def test_init_shape_and_determinism(model):
    md, fns = model
    a = jax.jit(fns.init)(jnp.int32(42))
    b = jax.jit(fns.init)(jnp.int32(42))
    c = jax.jit(fns.init)(jnp.int32(43))
    assert a.shape == (md.param_count,)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert not np.array_equal(np.asarray(a), np.asarray(c))
    assert np.isfinite(np.asarray(a)).all()


def test_biases_init_zero(model):
    md, fns = model
    flat = jax.jit(fns.init)(jnp.int32(0))
    params = unpack(flat, md.specs)
    for s in md.specs:
        if s.init == "zeros":
            np.testing.assert_array_equal(np.asarray(params[s.name]), 0.0)


def test_pack_unpack_roundtrip(model):
    md, fns = model
    flat = jax.jit(fns.init)(jnp.int32(1))
    np.testing.assert_array_equal(np.asarray(pack(unpack(flat, md.specs), md.specs)), np.asarray(flat))


def test_train_epoch_decreases_loss(model):
    md, fns = model
    xs, ys = _batches(md)
    flat = jax.jit(fns.init)(jnp.int32(0))
    train = jax.jit(fns.train_epoch)
    lr = jnp.float32(0.5 if md.task == "lm" else 0.05)
    _, first = train(flat, xs, ys, lr)
    for _ in range(4):
        flat, loss = train(flat, xs, ys, lr)
    assert float(loss) < float(first)
    assert np.isfinite(np.asarray(flat)).all()


def test_eval_chunk_counts(model):
    md, fns = model
    xs, ys = _batches(md)
    xs, ys = xs[: md.nb_eval], ys[: md.nb_eval]
    flat = jax.jit(fns.init)(jnp.int32(0))
    loss_sum, metric_sum, count = jax.jit(fns.eval_chunk)(flat, xs, ys)
    per_sample = int(np.prod(md.y_elem_shape)) if md.y_elem_shape else 1
    assert float(count) == md.nb_eval * md.batch * per_sample
    assert 0.0 <= float(metric_sum) <= float(count)
    assert float(loss_sum) > 0.0


def test_eval_improves_after_training(model):
    md, fns = model
    xs, ys = _batches(md)
    exs, eys = xs[: md.nb_eval], ys[: md.nb_eval]
    flat = jax.jit(fns.init)(jnp.int32(0))
    ev = jax.jit(fns.eval_chunk)
    before = ev(flat, exs, eys)
    train = jax.jit(fns.train_epoch)
    lr = jnp.float32(0.5 if md.task == "lm" else 0.05)
    for _ in range(5):
        flat, _ = train(flat, xs, ys, lr)
    after = ev(flat, exs, eys)
    assert float(after[0]) < float(before[0])  # loss_sum drops
    assert float(after[1]) >= float(before[1])  # correct count does not regress


def test_gradient_matches_finite_difference():
    """Spot-check jax.grad against central finite differences (lenet)."""
    md = REGISTRY["lenet"]
    fns = build_fns(md)
    xs, ys = _image_batch(md)
    x, y = xs[0], ys[0]
    flat = jax.jit(fns.init)(jnp.int32(0))
    g = jax.jit(jax.grad(fns.batch_loss))(flat, x, y)
    rng = np.random.default_rng(0)
    idxs = rng.choice(md.param_count, size=5, replace=False)
    eps = 1e-3
    f = jax.jit(fns.batch_loss)
    for i in idxs:
        e = np.zeros(md.param_count, np.float32)
        e[i] = eps
        num = (float(f(flat + e, x, y)) - float(f(flat - e, x, y))) / (2 * eps)
        assert abs(num - float(g[i])) < 5e-2 * max(1.0, abs(num))


def test_gru_tied_embedding_shares_parameters():
    """Tied projection: perturbing the embedding row changes that token's
    logit bias everywhere (no separate output matrix exists)."""
    md = REGISTRY["gru"]
    names = {s.name for s in md.specs}
    assert "embed" in names and "out_b" in names
    assert not any("out_w" in n for n in names)


def test_lm_logits_shape():
    md = REGISTRY["gru"]
    fns = build_fns(md)
    flat = jax.jit(fns.init)(jnp.int32(0))
    params = unpack(flat, md.specs)
    x = jnp.zeros((4, md.x_elem_shape[0]), jnp.int32)
    logits = md.apply_fn(params, x)
    assert logits.shape == (4, md.x_elem_shape[0], md.meta["vocab"])
