"""AOT path tests: HLO text artifacts are well-formed and the manifest is
consistent with the model definitions. Uses lenet (fastest to lower)."""

import json

import pytest

from compile.aot import hlo_op_histogram, lower_model, to_hlo_text
from compile.models import REGISTRY

import jax
import jax.numpy as jnp


@pytest.fixture(scope="module")
def lowered_lenet(tmp_path_factory):
    outdir = tmp_path_factory.mktemp("artifacts")
    entry = lower_model(REGISTRY["lenet"], outdir, verbose=False)
    return outdir, entry


def test_artifacts_exist_and_are_hlo_text(lowered_lenet):
    outdir, entry = lowered_lenet
    assert set(entry["artifacts"]) == {"init", "train", "eval", "mask"}
    for fname in entry["artifacts"].values():
        text = (outdir / fname).read_text()
        assert text.startswith("HloModule"), fname
        assert "ENTRY" in text


def test_manifest_entry_consistent(lowered_lenet):
    _, entry = lowered_lenet
    md = REGISTRY["lenet"]
    assert entry["p"] == md.param_count
    assert entry["batch"] == md.batch
    assert sum(l["size"] for l in entry["layers"]) == md.param_count
    masked = [l for l in entry["layers"] if l["masked"]]
    assert all(len(l["shape"]) >= 2 for l in masked)
    assert json.dumps(entry)  # serializable


def test_train_artifact_contains_no_python_callback(lowered_lenet):
    """The request path must be self-contained HLO: no host callbacks."""
    outdir, entry = lowered_lenet
    for fname in entry["artifacts"].values():
        text = (outdir / fname).read_text()
        assert "custom-call" not in text or "Callback" not in text, fname


def test_hlo_op_histogram_smoke():
    lowered = jax.jit(lambda x, y: (jnp.matmul(x, y) + 2.0,)).lower(
        jax.ShapeDtypeStruct((2, 2), jnp.float32), jax.ShapeDtypeStruct((2, 2), jnp.float32)
    )
    hist = hlo_op_histogram(to_hlo_text(lowered))
    assert hist.get("dot", 0) >= 1
