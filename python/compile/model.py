"""L2 facade: re-exports the model zoo for the documented entry point.

The actual definitions live in ``compile/models/`` (one module per client
learner); ``compile/aot.py`` lowers them. Import from here when scripting:

    from compile.model import REGISTRY, build_fns
"""

from compile.models import REGISTRY, ModelDef, ParamSpec, build_fns

__all__ = ["REGISTRY", "ModelDef", "ParamSpec", "build_fns"]
