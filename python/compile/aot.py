"""AOT compile path: lower every model's artifact set to HLO *text*.

Interchange format is HLO text, NOT a serialized ``HloModuleProto``:
jax >= 0.5 emits protos with 64-bit instruction ids that the image's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Per model four artifacts are produced (flat f32[P] calling convention):

  {m}_init.hlo.txt   (seed i32[])                          -> (f32[P],)
  {m}_train.hlo.txt  (f32[P], xs, ys, lr f32[])            -> (f32[P], f32[])
  {m}_eval.hlo.txt   (f32[P], xs, ys)                      -> (f32[], f32[], f32[])
  {m}_mask.hlo.txt   (f32[P], f32[P], gamma f32[])         -> (f32[P],)

plus ``manifest.json`` describing shapes + the per-layer table the rust
coordinator needs. Run via ``make artifacts``:

  cd python && python -m compile.aot --outdir ../artifacts
"""

from __future__ import annotations

import argparse
import collections
import functools
import json
import re
import sys
import time
from pathlib import Path

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile.kernels.selective_mask import selective_mask_layered
from compile.models import REGISTRY, ModelDef, build_fns

MANIFEST_VERSION = 2


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _dtype(name: str):
    return {"f32": jnp.float32, "i32": jnp.int32}[name]


def _shape_specs(md: ModelDef):
    p = md.param_count
    params = jax.ShapeDtypeStruct((p,), jnp.float32)
    xs_tr = jax.ShapeDtypeStruct((md.nb_train, md.batch, *md.x_elem_shape), _dtype(md.x_dtype))
    ys_tr = jax.ShapeDtypeStruct((md.nb_train, md.batch, *md.y_elem_shape), jnp.int32)
    xs_ev = jax.ShapeDtypeStruct((md.nb_eval, md.batch, *md.x_elem_shape), _dtype(md.x_dtype))
    ys_ev = jax.ShapeDtypeStruct((md.nb_eval, md.batch, *md.y_elem_shape), jnp.int32)
    scalar_f = jax.ShapeDtypeStruct((), jnp.float32)
    scalar_i = jax.ShapeDtypeStruct((), jnp.int32)
    return params, xs_tr, ys_tr, xs_ev, ys_ev, scalar_f, scalar_i


def lower_model(md: ModelDef, outdir: Path, verbose: bool = True) -> dict:
    """Lower one model's artifact set; returns its manifest entry."""
    fns = build_fns(md)
    params, xs_tr, ys_tr, xs_ev, ys_ev, scalar_f, scalar_i = _shape_specs(md)
    segments = md.mask_segments()

    mask_fn = functools.partial(selective_mask_layered, segments=segments)

    jobs = {
        "init": (fns.init, (scalar_i,)),
        "train": (fns.train_epoch, (params, xs_tr, ys_tr, scalar_f)),
        "eval": (fns.eval_chunk, (params, xs_ev, ys_ev)),
        "mask": (lambda wn, wo, g: mask_fn(wn, wo, g), (params, params, scalar_f)),
    }

    artifacts = {}
    for kind, (fn, args) in jobs.items():
        t0 = time.time()
        text = to_hlo_text(jax.jit(fn).lower(*args))
        fname = f"{md.name}_{kind}.hlo.txt"
        (outdir / fname).write_text(text)
        artifacts[kind] = fname
        if verbose:
            print(
                f"  {fname:28s} {len(text) / 1024:9.1f} KiB  ({time.time() - t0:.1f}s)",
                file=sys.stderr,
            )

    return {
        "p": md.param_count,
        "task": md.task,
        "batch": md.batch,
        "nb_train": md.nb_train,
        "nb_eval": md.nb_eval,
        "x_elem_shape": list(md.x_elem_shape),
        "x_dtype": md.x_dtype,
        "y_elem_shape": list(md.y_elem_shape),
        "layers": md.layer_table(),
        "meta": md.meta,
        "artifacts": artifacts,
    }


def hlo_op_histogram(text: str) -> dict:
    """Crude HLO instruction histogram for the --report L2 perf check."""
    hist = collections.Counter()
    for line in text.splitlines():
        m = re.match(r"\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*[\w\[\]{},\s/]*?\s([a-z][\w\-]*)\(", line)
        if m:
            hist[m.group(1)] += 1
    return dict(hist.most_common())


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--outdir", default="../artifacts", help="artifact output directory")
    ap.add_argument("--models", default=",".join(REGISTRY), help="comma-separated model subset")
    ap.add_argument("--report", action="store_true", help="print HLO op histograms")
    # legacy flag kept for the original Makefile stub; ignored if --outdir given
    ap.add_argument("--out", default=None, help=argparse.SUPPRESS)
    args = ap.parse_args()

    outdir = Path(args.outdir if args.out is None else Path(args.out).parent)
    outdir.mkdir(parents=True, exist_ok=True)
    names = [n.strip() for n in args.models.split(",") if n.strip()]
    unknown = [n for n in names if n not in REGISTRY]
    if unknown:
        ap.error(f"unknown models: {unknown}; available: {list(REGISTRY)}")

    manifest = {"version": MANIFEST_VERSION, "models": {}}
    for name in names:
        print(f"[aot] lowering {name}", file=sys.stderr)
        manifest["models"][name] = lower_model(REGISTRY[name], outdir)

    manifest_path = outdir / "manifest.json"
    manifest_path.write_text(json.dumps(manifest, indent=2))
    print(f"[aot] wrote {manifest_path}", file=sys.stderr)

    if args.report:
        for name in names:
            for kind, fname in manifest["models"][name]["artifacts"].items():
                hist = hlo_op_histogram((outdir / fname).read_text())
                top = ", ".join(f"{k}={v}" for k, v in list(hist.items())[:8])
                print(f"[report] {name}/{kind}: {top}")


if __name__ == "__main__":
    main()
