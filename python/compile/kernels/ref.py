"""Pure-jnp oracles for the L1 kernels.

These are the ground-truth implementations the Pallas kernels are tested
against (pytest + hypothesis in ``python/tests``). They use exact sort-based
top-k selection, which is simple and obviously correct but not TPU-shaped
(data-dependent gather patterns); the production kernel in
``selective_mask.py`` replaces the sort with threshold bisection.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = [
    "selective_mask_ref",
    "selective_mask_threshold_ref",
    "random_mask_ref",
]


def selective_mask_threshold_ref(w_new: jnp.ndarray, w_old: jnp.ndarray, gamma) -> jnp.ndarray:
    """Exact keep-threshold tau for selective masking (Eq. 4 of the paper).

    Returns the value tau such that keeping entries with |w_new - w_old| >= tau
    keeps (at least) ``clip(round(gamma * P), 1, P)`` entries — the keep-count
    convention shared with the Pallas kernel and the rust oracle
    (``fl/masking.rs`` ``keep_count``); ties at tau may keep more.
    """
    p = w_new.shape[0]
    d = jnp.abs(w_new - w_old)
    # gamma <= 0 keeps nothing (k == 0, tau = +inf) — same as the rust
    # keep_count; positive rates clamp into [1, p].
    k = jnp.where(
        jnp.asarray(gamma) > 0, jnp.clip(jnp.round(gamma * p), 1, p), 0
    ).astype(jnp.int32)
    sorted_desc = jnp.sort(d)[::-1]
    # k-th largest value
    tau = jnp.where(k >= 1, sorted_desc[jnp.clip(k - 1, 0, p - 1)], jnp.inf)
    return tau


def selective_mask_ref(w_new: jnp.ndarray, w_old: jnp.ndarray, gamma) -> jnp.ndarray:
    """Oracle for Alg. 4: keep the top-``round(gamma*P)`` entries of w_new by
    |w_new - w_old|, zero the rest (paper-literal: the *weights* are masked,
    not the delta)."""
    d = jnp.abs(w_new - w_old)
    tau = selective_mask_threshold_ref(w_new, w_old, gamma)
    return jnp.where(d >= tau, w_new, jnp.zeros_like(w_new))


def random_mask_ref(key: jax.Array, w: jnp.ndarray, gamma) -> jnp.ndarray:
    """Oracle for Alg. 2 (random masking): keep a Bernoulli(gamma) subset of
    entries of ``w``, zero the rest. The rust client implements the same
    policy with its deterministic splitmix RNG; this reference exists to
    validate distributional properties in tests."""
    keep = jax.random.uniform(key, w.shape) < gamma
    return jnp.where(keep, w, jnp.zeros_like(w))
