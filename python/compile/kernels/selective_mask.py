"""L1 Pallas kernel: selective top-k masking by |W_new - W_old| (Alg. 4).

TPU-shaped formulation
----------------------
A literal ``top_k`` needs a static k, but the paper sweeps the masking rate
gamma at runtime (Fig. 4/6/9), so the kernel instead finds the keep
*threshold* tau by fixed-iteration bisection:

  1. ``_absmax_kernel``  — one tiled pass computing per-block max |delta|.
  2. ``_count_kernel``   — per bisection step, one tiled pass counting
     entries with |delta| >= mid (block-local partial counts, reduced
     outside the kernel).
  3. ``_mask_kernel``    — one final tiled pass writing
     ``where(|delta| >= tau, w_new, 0)``.

All passes are streaming HBM->VMEM block sweeps with no data-dependent
shapes; |delta| is recomputed in-register in each pass rather than staged to
a P-sized buffer (bandwidth trade documented in DESIGN.md §6). Blocks are
(8,128)-aligned multiples for real-TPU VMEM tiling.

``interpret=True`` is mandatory on this CPU-only image: real TPU lowering
emits a Mosaic custom-call the CPU PJRT plugin cannot execute. Interpret
mode lowers the same structure to plain HLO, so the artifact runs anywhere.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

__all__ = ["selective_mask", "selective_mask_layered", "DEFAULT_BLOCK", "DEFAULT_ITERS"]

# Block size is the CPU<->TPU tuning knob. Interpret-mode lowering turns the
# grid into a while-loop whose per-step output write is a full-array
# dynamic-update-slice, so per-pass cost is O(P * nblk): small TPU-ish tiles
# are quadratic on CPU. Measured on P = 131072 (EXPERIMENTS.md §Perf):
# block 4096 -> 99.6 ms/call, 16384 -> 36.7, 65536 -> 13.0, 131072 -> 2.2.
# Default therefore covers every model segment in one block (<= 512 KiB of
# VMEM-equivalent, still well inside a real TPU core's ~16 MiB budget; for
# larger models re-tune toward multiples of (8, 128) tiles).
DEFAULT_BLOCK = 131072
# Bisection steps: interval shrinks by 2^-iters of dmax. 18 is already exact
# at P = 131072 against the sort oracle; 20 leaves margin for adversarial
# tie distributions at negligible cost (the count passes dominate).
DEFAULT_ITERS = 20


def _absmax_kernel(wn_ref, wo_ref, out_ref, *, valid_len, block):
    """Per-block max of |w_new - w_old| over the valid prefix."""
    pid = pl.program_id(0)
    d = jnp.abs(wn_ref[...] - wo_ref[...])
    idx = pid * block + lax.broadcasted_iota(jnp.int32, (block,), 0)
    d = jnp.where(idx < valid_len, d, 0.0)
    out_ref[0] = jnp.max(d)


def _count_kernel(mid_ref, wn_ref, wo_ref, out_ref, *, valid_len, block):
    """Per-block count of entries with |delta| >= mid (valid prefix only).

    Counts are f32: P < 2^24 for every model we lower, so the sum is exact.
    """
    pid = pl.program_id(0)
    d = jnp.abs(wn_ref[...] - wo_ref[...])
    idx = pid * block + lax.broadcasted_iota(jnp.int32, (block,), 0)
    ok = (d >= mid_ref[0]) & (idx < valid_len)
    out_ref[0] = jnp.sum(ok.astype(jnp.float32))


def _mask_kernel(tau_ref, wn_ref, wo_ref, out_ref):
    """Final masked write: keep w_new where |delta| >= tau, else 0."""
    d = jnp.abs(wn_ref[...] - wo_ref[...])
    out_ref[...] = jnp.where(d >= tau_ref[0], wn_ref[...], 0.0)


def selective_mask(
    w_new: jnp.ndarray,
    w_old: jnp.ndarray,
    gamma,
    *,
    block: int = DEFAULT_BLOCK,
    iters: int = DEFAULT_ITERS,
    interpret: bool = True,
) -> jnp.ndarray:
    """Keep the ~``round(gamma * P)`` entries of ``w_new`` with largest
    ``|w_new - w_old|``; zero the rest (paper Alg. 4, Eq. 4-5).

    ``gamma`` is a runtime scalar in [0, 1]. Bisection maintains the
    invariant count(d >= lo) >= k and count(d >= hi) < k, returning tau = lo,
    so the kept count is >= k and exceeds it only on f32-resolution ties.
    """
    p = w_new.shape[0]
    nblk = -(-p // block)
    pad = nblk * block - p
    wn = jnp.pad(w_new, (0, pad))
    wo = jnp.pad(w_old, (0, pad))
    grid = (nblk,)
    vec_spec = pl.BlockSpec((block,), lambda i: (i,))
    scalar_spec = pl.BlockSpec((1,), lambda i: (0,))
    part_spec = pl.BlockSpec((1,), lambda i: (i,))
    part_shape = jax.ShapeDtypeStruct((nblk,), jnp.float32)

    partial_max = pl.pallas_call(
        functools.partial(_absmax_kernel, valid_len=p, block=block),
        grid=grid,
        in_specs=[vec_spec, vec_spec],
        out_specs=part_spec,
        out_shape=part_shape,
        interpret=interpret,
    )(wn, wo)
    dmax = jnp.max(partial_max)

    # Shared keep-count convention with the rust oracle (fl/masking.rs
    # ``keep_count``): round(gamma * p) clamped to [1, p] for positive
    # rates — a non-empty segment with a positive rate never drops
    # everything (gamma -> 0), float round-off never overruns the segment
    # (gamma -> 1) — and gamma <= 0 keeps nothing.
    g = jnp.asarray(gamma, jnp.float32)
    k = jnp.where(g > 0, jnp.clip(jnp.round(g * p), 1.0, float(p)), 0.0)

    count_call = pl.pallas_call(
        functools.partial(_count_kernel, valid_len=p, block=block),
        grid=grid,
        in_specs=[scalar_spec, vec_spec, vec_spec],
        out_specs=part_spec,
        out_shape=part_shape,
        interpret=interpret,
    )

    def body(_, lohi):
        lo, hi = lohi
        mid = 0.5 * (lo + hi)
        cnt = jnp.sum(count_call(jnp.reshape(mid, (1,)), wn, wo))
        ge = cnt >= k
        return (jnp.where(ge, mid, lo), jnp.where(ge, hi, mid))

    # hi starts strictly above dmax so count(d >= hi) == 0 < k for k >= 1.
    hi0 = dmax * (1.0 + 1e-6) + 1e-30
    lo, hi = lax.fori_loop(0, iters, body, (jnp.float32(0.0), hi0))
    del hi
    # k == 0 (gamma <= 0): tau above dmax keeps nothing, matching the rust
    # keep_count boundary (config validation rejects the rate anyway).
    tau = jnp.where(k >= 1.0, lo, hi0)

    masked = pl.pallas_call(
        _mask_kernel,
        grid=grid,
        in_specs=[scalar_spec, vec_spec, vec_spec],
        out_specs=vec_spec,
        out_shape=jax.ShapeDtypeStruct((nblk * block,), jnp.float32),
        interpret=interpret,
    )(jnp.reshape(tau, (1,)), wn, wo)
    return masked[:p]


def selective_mask_layered(
    w_new: jnp.ndarray,
    w_old: jnp.ndarray,
    gamma,
    segments,
    *,
    block: int = DEFAULT_BLOCK,
    iters: int = DEFAULT_ITERS,
    interpret: bool = True,
) -> jnp.ndarray:
    """Paper-faithful per-layer masking (Alg. 4 loops over layers).

    ``segments`` is a static list of ``(offset, size, masked)`` triples over
    the flat parameter vector (from the model's layer table). Segments with
    ``masked=False`` (biases, 1-D tensors) pass through untouched; each
    masked segment gets its own top-k threshold, exactly as the paper's
    per-layer ``topk(D, gamma)``.
    """
    parts = []
    for offset, size, masked in segments:
        wn_seg = lax.slice(w_new, (offset,), (offset + size,))
        if not masked:
            parts.append(wn_seg)
            continue
        wo_seg = lax.slice(w_old, (offset,), (offset + size,))
        seg_block = min(block, -(-size // 128) * 128)
        parts.append(
            selective_mask(
                wn_seg, wo_seg, gamma, block=seg_block, iters=iters, interpret=interpret
            )
        )
    return jnp.concatenate(parts)
