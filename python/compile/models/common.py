"""Shared model machinery: flat-parameter packing, init, SGD epoch, eval.

The whole model lives in one ``f32[P]`` vector. ``aot.py`` bakes the layer
table (name/offset/size/shape/masked) into ``manifest.json`` so the rust
coordinator can do per-layer accounting without re-deriving shapes.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Callable

import jax
import jax.numpy as jnp
from jax import lax


@dataclass(frozen=True)
class ParamSpec:
    """One named parameter tensor inside the flat vector.

    ``init`` selects the initializer: ``glorot`` (fan-avg normal, weights),
    ``zeros`` (biases), or ``embed`` (N(0, 0.1), embedding tables).
    ``masked`` marks tensors eligible for selective/random masking — the
    paper's Alg. 2/4 mask per-*layer weight matrices*, so only ndim >= 2
    tensors default to maskable.
    """

    name: str
    shape: tuple
    init: str = "glorot"
    masked: bool | None = None

    @property
    def size(self) -> int:
        return int(math.prod(self.shape))

    @property
    def is_masked(self) -> bool:
        return self.masked if self.masked is not None else len(self.shape) >= 2


@dataclass(frozen=True)
class ModelDef:
    """Static description of a client learner + its batching geometry."""

    name: str
    task: str  # "image" | "lm"
    specs: tuple  # tuple[ParamSpec, ...]
    batch: int  # per-batch sample count B
    nb_train: int  # batches per local-epoch artifact call
    nb_eval: int  # batches per eval-chunk artifact call
    x_elem_shape: tuple  # per-sample input shape
    x_dtype: str  # "f32" | "i32"
    y_elem_shape: tuple  # per-sample label shape (() image, (T,) lm)
    apply_fn: Callable  # (params: dict, x_batch) -> logits
    meta: dict = field(default_factory=dict)

    @property
    def param_count(self) -> int:
        return sum(s.size for s in self.specs)

    def layer_table(self) -> list[dict]:
        """Layer table for manifest.json (offsets into the flat vector)."""
        table, offset = [], 0
        for s in self.specs:
            table.append(
                {
                    "name": s.name,
                    "shape": list(s.shape),
                    "offset": offset,
                    "size": s.size,
                    "masked": s.is_masked,
                }
            )
            offset += s.size
        return table

    def mask_segments(self) -> list[tuple]:
        """(offset, size, masked) triples for the L1 layered mask kernel."""
        return [(t["offset"], t["size"], t["masked"]) for t in self.layer_table()]


def unpack(flat: jnp.ndarray, specs) -> dict:
    """Split the flat vector into named, shaped tensors (inside the HLO)."""
    params, offset = {}, 0
    for s in specs:
        params[s.name] = lax.slice(flat, (offset,), (offset + s.size,)).reshape(s.shape)
        offset += s.size
    return params


def pack(params: dict, specs) -> jnp.ndarray:
    return jnp.concatenate([params[s.name].reshape(-1) for s in specs])


def _init_one(key: jax.Array, spec: ParamSpec) -> jnp.ndarray:
    if spec.init == "zeros":
        return jnp.zeros(spec.shape, jnp.float32)
    if spec.init == "embed":
        return 0.1 * jax.random.normal(key, spec.shape, jnp.float32)
    # glorot normal; for conv HWIO tensors fan_in/out include the window.
    if len(spec.shape) == 4:
        rf = spec.shape[0] * spec.shape[1]
        fan_in, fan_out = rf * spec.shape[2], rf * spec.shape[3]
    elif len(spec.shape) == 2:
        fan_in, fan_out = spec.shape
    else:
        fan_in = fan_out = spec.size
    scale = math.sqrt(2.0 / float(fan_in + fan_out))
    return scale * jax.random.normal(key, spec.shape, jnp.float32)


def _image_batch_loss(md: ModelDef, params: dict, x, y):
    logits = md.apply_fn(params, x)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, y[:, None], axis=-1)[:, 0]
    return jnp.mean(nll)


def _lm_batch_loss(md: ModelDef, params: dict, x, y):
    logits = md.apply_fn(params, x)  # [B, T, V]
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, y[..., None], axis=-1)[..., 0]
    return jnp.mean(nll)


@dataclass(frozen=True)
class ModelFns:
    """The four traceable functions lowered to HLO artifacts."""

    init: Callable  # (seed i32[]) -> f32[P]
    train_epoch: Callable  # (f32[P], xs, ys, lr f32[]) -> (f32[P], f32[])
    eval_chunk: Callable  # (f32[P], xs, ys) -> (loss_sum, metric_sum, count)
    batch_loss: Callable  # (f32[P], x, y) -> f32[]


def build_fns(md: ModelDef) -> ModelFns:
    specs = md.specs
    per_batch_loss = _lm_batch_loss if md.task == "lm" else _image_batch_loss

    def batch_loss(flat, x, y):
        return per_batch_loss(md, unpack(flat, specs), x, y)

    def init(seed):
        key = jax.random.PRNGKey(seed)
        return jnp.concatenate(
            [_init_one(jax.random.fold_in(key, i), s).reshape(-1) for i, s in enumerate(specs)]
        )

    def train_epoch(flat, xs, ys, lr):
        """One local epoch: plain SGD (paper Alg. 2/4 line 8), scanned over
        NB static batches so the rust->PJRT call count is 1 per epoch."""

        def step(carry, batch):
            x, y = batch
            loss, grad = jax.value_and_grad(batch_loss)(carry, x, y)
            return carry - lr * grad, loss

        flat, losses = lax.scan(step, flat, (xs, ys))
        return flat, jnp.mean(losses)

    def eval_chunk(flat, xs, ys):
        """Scanned eval: returns (loss_sum, metric_sum, count). metric is
        correct-prediction count (argmax == label) for both tasks; for the
        LM task the coordinator derives perplexity as exp(loss_sum/count)."""

        def step(acc, batch):
            x, y = batch
            params = unpack(flat, specs)
            logits = md.apply_fn(params, x)
            logp = jax.nn.log_softmax(logits, axis=-1)
            nll = -jnp.take_along_axis(logp, y[..., None], axis=-1)[..., 0]
            correct = jnp.sum((jnp.argmax(logits, axis=-1) == y).astype(jnp.float32))
            n = jnp.float32(nll.size)
            return (acc[0] + jnp.sum(nll), acc[1] + correct, acc[2] + n), None

        (loss_sum, metric_sum, count), _ = lax.scan(
            step, (jnp.float32(0.0), jnp.float32(0.0), jnp.float32(0.0)), (xs, ys)
        )
        return loss_sum, metric_sum, count

    return ModelFns(init=init, train_epoch=train_epoch, eval_chunk=eval_chunk, batch_loss=batch_loss)


def conv2d(x, w, b, *, padding="VALID"):
    """NHWC conv + bias (HWIO weights)."""
    y = lax.conv_general_dilated(
        x, w, window_strides=(1, 1), padding=padding, dimension_numbers=("NHWC", "HWIO", "NHWC")
    )
    return y + b


def maxpool2(x):
    """2x2 max pool, stride 2, NHWC."""
    return lax.reduce_window(
        x, -jnp.inf, lax.max, window_dimensions=(1, 2, 2, 1), window_strides=(1, 2, 2, 1), padding="VALID"
    )
