"""VGG-mini: VGG-family conv net for 32x32x3 10-class images.

The paper runs VGG-16 (~138M params) on CIFAR-10; that is infeasible on this
single-core CPU testbed (a 205k-param variant already costs ~13 s per
scanned train chunk — measured, see EXPERIMENTS.md §Perf), so we keep the
VGG idiom — stacked 3x3 conv-conv-pool blocks with doubling channel widths
and an FC head — at a width the figure sweeps can afford (DESIGN.md §2
substitution table). Still ~2.5x LeNet's parameter count and ~8x its
per-sample FLOPs, preserving the "large conv model" contrast of Fig. 6/7.

block1: 3 -> 8 -> 8, pool  32 -> 16
block2: 8 ->16 ->16, pool  16 ->  8
block3: 16->32 ->32, pool   8 ->  4
fc(512 -> 64) -> relu -> fc(64 -> 10)

P = 51,666 parameters.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from compile.models.common import ModelDef, ParamSpec, conv2d, maxpool2

SPECS = (
    ParamSpec("b1c1_w", (3, 3, 3, 8)),
    ParamSpec("b1c1_b", (8,), init="zeros"),
    ParamSpec("b1c2_w", (3, 3, 8, 8)),
    ParamSpec("b1c2_b", (8,), init="zeros"),
    ParamSpec("b2c1_w", (3, 3, 8, 16)),
    ParamSpec("b2c1_b", (16,), init="zeros"),
    ParamSpec("b2c2_w", (3, 3, 16, 16)),
    ParamSpec("b2c2_b", (16,), init="zeros"),
    ParamSpec("b3c1_w", (3, 3, 16, 32)),
    ParamSpec("b3c1_b", (32,), init="zeros"),
    ParamSpec("b3c2_w", (3, 3, 32, 32)),
    ParamSpec("b3c2_b", (32,), init="zeros"),
    ParamSpec("fc1_w", (512, 64)),
    ParamSpec("fc1_b", (64,), init="zeros"),
    ParamSpec("fc2_w", (64, 10)),
    ParamSpec("fc2_b", (10,), init="zeros"),
)


def apply(p: dict, x: jnp.ndarray) -> jnp.ndarray:
    """x: f32[B, 32, 32, 3] -> logits f32[B, 10]."""
    h = x
    for blk in ("b1", "b2", "b3"):
        h = jax.nn.relu(conv2d(h, p[f"{blk}c1_w"], p[f"{blk}c1_b"], padding="SAME"))
        h = jax.nn.relu(conv2d(h, p[f"{blk}c2_w"], p[f"{blk}c2_b"], padding="SAME"))
        h = maxpool2(h)
    h = h.reshape(h.shape[0], -1)
    h = jax.nn.relu(h @ p["fc1_w"] + p["fc1_b"])
    return h @ p["fc2_w"] + p["fc2_b"]


model_def = ModelDef(
    name="vggmini",
    task="image",
    specs=SPECS,
    batch=32,
    nb_train=4,
    nb_eval=4,
    x_elem_shape=(32, 32, 3),
    x_dtype="f32",
    y_elem_shape=(),
    apply_fn=apply,
    meta={"classes": 10, "paper_model": "VGG-16 [31] on CIFAR-10 (scaled, see DESIGN.md)"},
)
