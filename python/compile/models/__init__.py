"""L2 model zoo: the paper's three client learners as pure-JAX fwd/bwd.

Each model is described by a :class:`~compile.models.common.ModelDef` and
lowered by ``aot.py`` to four HLO-text artifacts (init / train / eval / mask)
with a flat ``f32[P]`` parameter calling convention — see DESIGN.md §1.
"""

from compile.models.common import ModelDef, ParamSpec, build_fns
from compile.models import gru, lenet, vggmini

REGISTRY = {
    "lenet": lenet.model_def,
    "vggmini": vggmini.model_def,
    "gru": gru.model_def,
}

__all__ = ["ModelDef", "ParamSpec", "build_fns", "REGISTRY"]
