"""LeNet-5-style CNN for 28x28x1 10-class images (paper: LeNet on MNIST).

conv(1->8, 5x5, VALID) -> relu -> pool2   28 -> 24 -> 12
conv(8->16, 5x5, VALID) -> relu -> pool2  12 ->  8 ->  4
fc(256 -> 64) -> relu -> fc(64 -> 10)

P = 20,522 parameters.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from compile.models.common import ModelDef, ParamSpec, conv2d, maxpool2

SPECS = (
    ParamSpec("conv1_w", (5, 5, 1, 8)),
    ParamSpec("conv1_b", (8,), init="zeros"),
    ParamSpec("conv2_w", (5, 5, 8, 16)),
    ParamSpec("conv2_b", (16,), init="zeros"),
    ParamSpec("fc1_w", (256, 64)),
    ParamSpec("fc1_b", (64,), init="zeros"),
    ParamSpec("fc2_w", (64, 10)),
    ParamSpec("fc2_b", (10,), init="zeros"),
)


def apply(p: dict, x: jnp.ndarray) -> jnp.ndarray:
    """x: f32[B, 28, 28, 1] -> logits f32[B, 10]."""
    h = jax.nn.relu(conv2d(x, p["conv1_w"], p["conv1_b"]))
    h = maxpool2(h)
    h = jax.nn.relu(conv2d(h, p["conv2_w"], p["conv2_b"]))
    h = maxpool2(h)
    h = h.reshape(h.shape[0], -1)
    h = jax.nn.relu(h @ p["fc1_w"] + p["fc1_b"])
    return h @ p["fc2_w"] + p["fc2_b"]


model_def = ModelDef(
    name="lenet",
    task="image",
    specs=SPECS,
    batch=32,
    nb_train=8,
    nb_eval=8,
    x_elem_shape=(28, 28, 1),
    x_dtype="f32",
    y_elem_shape=(),
    apply_fn=apply,
    meta={"classes": 10, "paper_model": "LeNet [18] on MNIST"},
)
