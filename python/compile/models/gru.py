"""GRU language model with tied input/output embeddings (paper §5.3).

The paper uses a GRU [5] client learner on WikiText-2 with tied word
embedding and classifier [9, 29] to cut communication. We keep exactly that
structure at vocab V=2000 / d=64 / seq T=32 (corpus substitution documented
in DESIGN.md §2).

embed (V, 64), tied with the output projection (logits = h @ embed^T + b).
GRU gates use concatenated [x, h] weights of shape (128, 64).

P = 154,768 parameters.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from compile.models.common import ModelDef, ParamSpec

VOCAB = 2000
DIM = 64
SEQ = 32

SPECS = (
    ParamSpec("embed", (VOCAB, DIM), init="embed"),
    ParamSpec("gru_wz", (2 * DIM, DIM)),
    ParamSpec("gru_bz", (DIM,), init="zeros"),
    ParamSpec("gru_wr", (2 * DIM, DIM)),
    ParamSpec("gru_br", (DIM,), init="zeros"),
    ParamSpec("gru_wh", (2 * DIM, DIM)),
    ParamSpec("gru_bh", (DIM,), init="zeros"),
    ParamSpec("out_b", (VOCAB,), init="zeros"),
)


def apply(p: dict, x: jnp.ndarray) -> jnp.ndarray:
    """x: i32[B, T] token ids -> logits f32[B, T, V] (next-token)."""
    emb = p["embed"][x]  # [B, T, D]
    batch = emb.shape[0]
    h0 = jnp.zeros((batch, DIM), jnp.float32)

    def cell(h, xt):
        hx = jnp.concatenate([xt, h], axis=-1)
        z = jax.nn.sigmoid(hx @ p["gru_wz"] + p["gru_bz"])
        r = jax.nn.sigmoid(hx @ p["gru_wr"] + p["gru_br"])
        hxr = jnp.concatenate([xt, r * h], axis=-1)
        h_tilde = jnp.tanh(hxr @ p["gru_wh"] + p["gru_bh"])
        h_new = (1.0 - z) * h + z * h_tilde
        return h_new, h_new

    _, hs = lax.scan(cell, h0, emb.transpose(1, 0, 2))  # hs: [T, B, D]
    logits = hs @ p["embed"].T + p["out_b"]  # tied projection, [T, B, V]
    return logits.transpose(1, 0, 2)


model_def = ModelDef(
    name="gru",
    task="lm",
    specs=SPECS,
    batch=16,
    nb_train=8,
    nb_eval=8,
    x_elem_shape=(SEQ,),
    x_dtype="i32",
    y_elem_shape=(SEQ,),
    apply_fn=apply,
    meta={
        "vocab": VOCAB,
        "dim": DIM,
        "seq": SEQ,
        "paper_model": "GRU [5] LM on WikiText-2, tied embeddings [9,29]",
    },
)
